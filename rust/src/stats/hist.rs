//! Log-domain magnitude histograms — the Fig. 2 visualization substrate
//! (neural-gradient distribution before/after each LUQ stage) and the
//! lognormality diagnostics.

/// Histogram over `log2|x|` with fixed-width bins; zeros counted aside.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
    pub zeros: u64,
    pub total: u64,
}

impl LogHistogram {
    /// `lo`, `hi`: log2-magnitude range; values outside clamp to the edge
    /// bins (keeps tails visible without unbounded storage).
    pub fn new(lo: f32, hi: f32, bins: usize) -> Self {
        assert!(hi > lo && bins >= 2);
        LogHistogram { lo, hi, counts: vec![0; bins], zeros: 0, total: 0 }
    }

    pub fn add(&mut self, x: f32) {
        self.total += 1;
        if x == 0.0 {
            self.zeros += 1;
            return;
        }
        let l = x.abs().log2();
        let n = self.counts.len();
        let t = ((l - self.lo) / (self.hi - self.lo) * n as f32).floor();
        let idx = (t.max(0.0) as usize).min(n - 1);
        self.counts[idx] += 1;
    }

    pub fn add_slice(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Bin centers in log2 space.
    pub fn centers(&self) -> Vec<f32> {
        let n = self.counts.len() as f32;
        (0..self.counts.len())
            .map(|i| self.lo + (i as f32 + 0.5) / n * (self.hi - self.lo))
            .collect()
    }

    /// Fraction of non-zero mass in each bin.
    pub fn density(&self) -> Vec<f64> {
        let nz = (self.total - self.zeros).max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / nz).collect()
    }

    /// Fraction of exact zeros (LUQ's stochastic pruning creates these).
    pub fn zero_fraction(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.zeros as f64 / self.total as f64
        }
    }

    /// Number of distinct non-empty bins — after LUQ this collapses to at
    /// most the number of format levels (the Fig. 2 "comb").
    pub fn support_size(&self) -> usize {
        self.counts.iter().filter(|&&c| c > 0).count()
    }

    /// Render as rows `(log2_center, density)` for the experiment logs.
    pub fn rows(&self) -> Vec<(f32, f64)> {
        self.centers().into_iter().zip(self.density()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{LogFormat, LogQuantConfig, LogQuantizer};
    use crate::rng::Xoshiro256;

    #[test]
    fn counts_and_zeros() {
        let mut h = LogHistogram::new(-4.0, 4.0, 8);
        h.add_slice(&[0.0, 1.0, -1.0, 2.0, 0.0625]);
        assert_eq!(h.total, 5);
        assert_eq!(h.zeros, 1);
        assert_eq!(h.counts.iter().sum::<u64>(), 4);
    }

    #[test]
    fn out_of_range_clamps_to_edges() {
        let mut h = LogHistogram::new(-1.0, 1.0, 4);
        h.add(1e-10); // log2 ~ -33 -> bin 0
        h.add(1e10); // log2 ~ 33  -> bin 3
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[3], 1);
    }

    #[test]
    fn gaussian_in_log_domain_is_unimodal() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut h = LogHistogram::new(-12.0, 6.0, 36);
        for _ in 0..100_000 {
            h.add(rng.signed_lognormal_f32(0.0, 2.0));
        }
        // lognormal magnitudes -> normal in log2 domain: peak near 0.
        let d = h.density();
        let peak = d
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let c = h.centers()[peak];
        assert!(c.abs() < 1.5, "peak at log2={c}");
    }

    #[test]
    fn luq_collapses_support_to_format_levels() {
        // The Fig. 2 effect: after LUQ the histogram support is exactly
        // the format's levels (7 for FP4).
        let mut rng = Xoshiro256::seed_from_u64(2);
        let x: Vec<f32> = (0..50_000).map(|_| rng.signed_lognormal_f32(0.0, 2.0)).collect();
        let q = LogQuantizer::new(LogQuantConfig::luq(LogFormat::FP4));
        let (y, _) = q.quantize(&x, &mut rng);
        let mut h = LogHistogram::new(-20.0, 16.0, 720);
        h.add_slice(&y);
        assert_eq!(h.support_size(), 7, "FP4 has 7 magnitude levels");
        assert!(h.zero_fraction() > 0.0, "stochastic pruning must create zeros");
    }
}
