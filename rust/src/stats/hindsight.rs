//! In-hindsight range estimation (Fournarakis & Nagel 2021), as adopted by
//! the paper (§4.3 "Reducing the data movement", Eq. 24):
//!
//! ```text
//!   m̂_t = (1 − η) · max|x_{t−1}| + η · m̂_{t−1}
//! ```
//!
//! The quantizer at step *t* uses `m̂_t` — computed entirely from *previous*
//! iterations — so the max-reduction of the current tensor happens in
//! parallel with (not before) quantization, removing a full read of the
//! tensor from the critical path. Table 3 / Fig. 6 show the accuracy cost
//! is negligible.

/// EMA max tracker for one tensor (one per layer-gradient in training).
#[derive(Clone, Debug)]
pub struct HindsightMax {
    /// Momentum η (the paper uses η = 0.1).
    pub eta: f32,
    est: Option<f32>,
}

impl HindsightMax {
    pub fn new(eta: f32) -> Self {
        assert!((0.0..1.0).contains(&eta));
        HindsightMax { eta, est: None }
    }

    /// The estimate to use for the *current* step. `None` until the first
    /// observation (callers fall back to a measured max on step 0).
    pub fn estimate(&self) -> Option<f32> {
        self.est
    }

    /// Feed the measured max of the step that just completed (Eq. 24).
    pub fn observe(&mut self, measured_max: f32) {
        self.est = Some(match self.est {
            None => measured_max,
            Some(prev) => (1.0 - self.eta) * measured_max + self.eta * prev,
        });
    }

    /// Relative error of the current estimate vs a measured max
    /// (positive = overestimate). Used by the Fig. 6 trace.
    pub fn relative_error(&self, measured_max: f32) -> Option<f32> {
        self.est.map(|e| (e - measured_max) / measured_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn first_observation_seeds_estimate() {
        let mut h = HindsightMax::new(0.1);
        assert!(h.estimate().is_none());
        h.observe(5.0);
        assert_eq!(h.estimate(), Some(5.0));
    }

    #[test]
    fn ema_recurrence_matches_eq24() {
        let mut h = HindsightMax::new(0.1);
        h.observe(10.0);
        h.observe(20.0);
        // m̂ = 0.9 * 20 + 0.1 * 10 = 19
        assert!((h.estimate().unwrap() - 19.0).abs() < 1e-6);
        h.observe(5.0);
        assert!((h.estimate().unwrap() - (0.9 * 5.0 + 0.1 * 19.0)).abs() < 1e-6);
    }

    #[test]
    fn converges_to_stationary_max() {
        let mut h = HindsightMax::new(0.1);
        for _ in 0..100 {
            h.observe(3.0);
        }
        assert!((h.estimate().unwrap() - 3.0).abs() < 1e-5);
    }

    #[test]
    fn tracks_slowly_varying_max_closely() {
        // Fig. 6's claim: on real gradient traces the estimate hugs the
        // measured max. Simulate a noisy, slowly decaying max trace.
        let mut h = HindsightMax::new(0.1);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut worst = 0.0f32;
        for t in 0..500 {
            let base = 10.0 * (-(t as f32) / 300.0).exp();
            let measured = base * rng.uniform_range_f32(0.8, 1.2);
            if let Some(err) = h.relative_error(measured) {
                if t > 10 {
                    worst = worst.max(err.abs());
                }
            }
            h.observe(measured);
        }
        assert!(worst < 0.5, "worst relative error {worst}");
    }
}
