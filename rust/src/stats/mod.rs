//! Tensor-statistics substrate: the hindsight range estimator (Eq. 24,
//! Fig. 6, Table 3), histograms (Fig. 2), and the bias/variance/MSE
//! estimators used across the experiments.

pub mod hindsight;
pub mod hist;
pub mod moments;

pub use hindsight::HindsightMax;
pub use hist::LogHistogram;
pub use moments::{bias_variance_mse, cosine_similarity, Moments};
