//! Moment accumulators and the bias/variance/MSE decomposition (Eq. 7)
//! used to characterize quantizers empirically.

/// Streaming mean/variance/min/max accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Moments {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Moments {
    pub fn new() -> Self {
        Moments { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn add_slice(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x as f64);
        }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            f64::INFINITY
        } else {
            (self.variance() / self.n as f64).sqrt()
        }
    }
}

/// Empirical decomposition `MSE = Var + Bias²` (Eq. 7) of a stochastic
/// quantizer at a fixed input: feed repeated samples `q_i = Q(x)`.
/// Returns `(bias, variance, mse)`; the identity is exact up to the
/// estimators' own noise and is asserted in tests.
pub fn bias_variance_mse(x: f64, samples: &[f64]) -> (f64, f64, f64) {
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let bias = mean - x;
    let var = samples.iter().map(|q| (q - mean).powi(2)).sum::<f64>() / n;
    let mse = samples.iter().map(|q| (q - x).powi(2)).sum::<f64>() / n;
    (bias, var, mse)
}

/// Cosine similarity between two vectors — the standard "gradient
/// direction preserved?" diagnostic for quantized training.
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b.iter()) {
        dot += x as f64 * y as f64;
        na += (x as f64).powi(2);
        nb += (y as f64).powi(2);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{LogFormat, LogQuantConfig, LogQuantizer};
    use crate::rng::Xoshiro256;

    #[test]
    fn welford_matches_two_pass() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let xs: Vec<f32> = (0..10_000).map(|_| rng.normal_ms_f32(3.0, 2.0)).collect();
        let mut m = Moments::new();
        m.add_slice(&xs);
        let mean = xs.iter().map(|&v| v as f64).sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((m.mean() - mean).abs() < 1e-9);
        assert!((m.variance() - var).abs() / var < 1e-9);
    }

    #[test]
    fn decomposition_identity_eq7() {
        // MSE == Var + Bias² exactly when all three use the same samples.
        let samples = [1.0, 2.0, 2.0, 3.0, 1.5];
        let x = 1.8;
        let (b, v, mse) = bias_variance_mse(x, &samples);
        assert!((mse - (v + b * b)).abs() < 1e-12);
    }

    #[test]
    fn luq_decomposition_bias_near_zero_variance_positive() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let q = LogQuantizer::new(LogQuantConfig::luq(LogFormat::FP4));
        let x = vec![64.0f32, 2.9];
        let samples: Vec<f64> = (0..50_000)
            .map(|_| q.quantize(&x, &mut rng).0[1] as f64)
            .collect();
        let (bias, var, mse) = bias_variance_mse(2.9, &samples);
        assert!(bias.abs() < 0.02, "bias {bias}");
        assert!(var > 0.1, "var {var}");
        assert!((mse - (var + bias * bias)).abs() < 1e-9);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine_similarity(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((cosine_similarity(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine_similarity(&[1.0, 2.0], &[-1.0, -2.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine_similarity(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn luq_preserves_gradient_direction() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let x: Vec<f32> = (0..8192).map(|_| rng.signed_lognormal_f32(0.0, 2.0)).collect();
        let q = LogQuantizer::new(LogQuantConfig::luq(LogFormat::FP4));
        let (y, _) = q.quantize(&x, &mut rng);
        let cs = cosine_similarity(&x, &y);
        assert!(cs > 0.95, "cosine {cs}");
    }
}
