//! `tidy` — dependency-free static analysis for the repo's contracts.
//!
//! LUQ's accuracy and perf claims rest on invariants the type system cannot
//! see: unbiased stochastic rounding needs every RNG draw site accounted for
//! (one unregistered `uniform_f32` silently breaks the pinned draw-accounting
//! contracts), and the perf architecture needs the `*_into`/`*_scratch` hot
//! paths to stay allocation-free. This binary is a token-level scanner over
//! `rust/src/**` (plus `benches/*.rs` for the coverage rule) that turns those
//! conventions into a mechanical gate. Pure std, zero dependencies, runs in
//! well under a second; `scripts/check.sh` runs it first and CI has a
//! fast-fail `tidy` job.
//!
//! Rules (see README "Static analysis & contracts" for the full story):
//!
//! * `hot-path-alloc` — functions named `*_into`/`*_scratch` under `quant/`,
//!   `hw/`, `rng/` and in `coordinator/layer_step.rs` must contain no
//!   allocation tokens (`Vec::new`, `vec!`, `to_vec`, `collect`, `Box::new`,
//!   `with_capacity`, `clone`).
//! * `rng-registry` — every `uniform_f32`/`fill_uniform`/`next_u64` call
//!   site outside `rng/`, `testutil/` and test code must appear in the
//!   checked-in `tidy/draw_sites.txt` as `<path> <fn> <token>`.
//! * `coverage` — every `ForwardFormat` variant, every `FaultClass` variant,
//!   every `KernelPath` variant, every `ProductLut` instantiation (a fn
//!   returning `&'static ProductLut` in `hw/qgemm.rs`), every
//!   `ShardConfig` constructor (a fn returning `ShardConfig` in
//!   `hw/qgemm.rs`), and every `StepProfile` constructor (a fn returning
//!   `StepProfile` or `Result<StepProfile, _>` in `coordinator/profile.rs`)
//!   must be referenced in `testutil/conformance.rs`, the bench ladder
//!   (`benches/*.rs`), and the fault suite (`testutil/fault_suite.rs`);
//!   fault classes in the fault suite only.
//! * `panic-policy` — `unwrap()`/`expect()`/`panic!`/`unreachable!` in
//!   non-test library code are counted against `tidy/panic_budget.txt`,
//!   whose number may only shrink.
//! * `safety-comment` — every `unsafe` token needs a `// SAFETY:` comment on
//!   the same line or within the two lines above it.
//!
//! Any rule can be waived at a single site with an inline comment on the
//! same line or the line directly above:
//!
//! ```text
//! // tidy-allow: <rule-name> (one-line reason)
//! ```
//!
//! The scanner masks string literals, char literals and comments before
//! matching tokens, so prose and format strings never trip a rule; comments
//! are kept aside for the `tidy-allow` / `SAFETY:` checks. It is a token
//! scanner, not a parser: it can be fooled on purpose, but not by accident.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Allocation tokens banned in hot-path functions.
const ALLOC_TOKENS: &[&str] =
    &["Vec::new", "vec!", "to_vec", "collect", "Box::new", "with_capacity", "clone"];

/// RNG draw tokens that must be registered outside `rng/`.
const DRAW_TOKENS: &[&str] = &["uniform_f32", "fill_uniform", "next_u64"];

const REGISTRY_PATH: &str = "tidy/draw_sites.txt";
const BUDGET_PATH: &str = "tidy/panic_budget.txt";

const HINT_HOT_ALLOC: &str = "move the allocation to a caller-owned scratch/buffer, or waive \
                              with `// tidy-allow: hot-path-alloc (reason)`";
const HINT_RNG: &str = "add the printed line to tidy/draw_sites.txt and re-derive the layer's \
                        draw-accounting contract, or waive with `// tidy-allow: rng-registry \
                        (reason)`";
const HINT_COVERAGE: &str = "reference the item from testutil/conformance.rs, benches/*.rs and \
                             testutil/fault_suite.rs (fault classes: fault suite only), or waive \
                             at the definition with `// tidy-allow: coverage (reason)`";
const HINT_PANIC: &str = "propagate a Result instead, waive with `// tidy-allow: panic-policy \
                          (reason)`, or — only when burning sites down — lower \
                          tidy/panic_budget.txt";
const HINT_SAFETY: &str = "add a `// SAFETY: ...` comment on the unsafe line or within the two \
                           lines above it";

#[derive(Clone, Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
    hint: &'static str,
}

/// One scanned source file with everything the rules need precomputed.
struct SourceFile {
    rel: String,
    /// Source with comments, strings and char literals blanked to spaces
    /// (newlines preserved, so byte offsets and line numbers still map).
    masked: Vec<u8>,
    /// `(line, text)` for every comment line, kept for `tidy-allow` and
    /// `SAFETY:` detection.
    comments: Vec<(usize, String)>,
    /// Byte offset of the start of each line.
    line_starts: Vec<usize>,
    /// Byte ranges covered by `#[cfg(test)]` / `#[test]` items.
    test_ranges: Vec<(usize, usize)>,
    fns: Vec<FnItem>,
}

#[derive(Clone, Debug)]
struct FnItem {
    name: String,
    /// Byte offset of the name token.
    name_pos: usize,
    /// End of the declaration: the body `{` or the terminating `;`.
    decl_end: usize,
    /// Byte range of the `{ ... }` body, if the fn has one.
    body: Option<(usize, usize)>,
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn blank(out: &mut [u8], from: usize, to: usize) {
    for byte in &mut out[from..to.min(out.len())] {
        if *byte != b'\n' {
            *byte = b' ';
        }
    }
}

/// Skip a `"..."` string literal starting at `i` (the opening quote),
/// returning the offset just past the closing quote.
fn skip_string(b: &[u8], i: usize) -> usize {
    let mut j = i + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            _ => j += 1,
        }
    }
    j
}

/// Skip a raw string starting at the first `#` or `"` after the `r`/`br`
/// prefix; returns the offset past the closing delimiter, or `None` if this
/// is not actually a raw string (e.g. a raw identifier like `r#fn`).
fn skip_raw_string(b: &[u8], after_prefix: usize) -> Option<usize> {
    let mut hashes = 0usize;
    let mut j = after_prefix;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != b'"' {
        return None;
    }
    j += 1;
    while j < b.len() {
        if b[j] == b'"'
            && b.len() - j > hashes
            && b[j + 1..j + 1 + hashes].iter().all(|&h| h == b'#')
        {
            return Some(j + 1 + hashes);
        }
        j += 1;
    }
    Some(j)
}

/// Skip a char (or byte-char) literal starting at the opening `'`. Returns
/// `None` when the quote is a lifetime/label rather than a literal.
fn skip_char_literal(b: &[u8], i: usize, force_literal: bool) -> Option<usize> {
    if i + 1 >= b.len() {
        return None;
    }
    if b[i + 1] == b'\\' {
        let mut j = i + 2;
        while j < b.len() {
            match b[j] {
                b'\\' => j += 2,
                b'\'' => return Some(j + 1),
                _ => j += 1,
            }
        }
        return Some(j);
    }
    if i + 2 < b.len() && b[i + 2] == b'\'' && b[i + 1] != b'\'' {
        return Some(i + 3);
    }
    if force_literal {
        // b'x' is never a lifetime; scan to the closing quote defensively.
        let mut j = i + 1;
        while j < b.len() && b[j] != b'\'' && b[j] != b'\n' {
            j += 1;
        }
        return Some((j + 1).min(b.len()));
    }
    None
}

/// Blank comments, strings and char literals; collect comment text by line.
fn mask(src: &str) -> (Vec<u8>, Vec<(usize, String)>) {
    let b = src.as_bytes();
    let mut out = b.to_vec();
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // Line comment.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let mut j = i;
            while j < b.len() && b[j] != b'\n' {
                j += 1;
            }
            comments.push((line, String::from_utf8_lossy(&b[i..j]).into_owned()));
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // Block comment (nested), recorded line by line.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            let mut seg = i;
            while j < b.len() && depth > 0 {
                if b[j] == b'\n' {
                    comments.push((line, String::from_utf8_lossy(&b[seg..j]).into_owned()));
                    line += 1;
                    j += 1;
                    seg = j;
                } else if b[j] == b'/' && j + 1 < b.len() && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < b.len() && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            if seg < j {
                comments.push((line, String::from_utf8_lossy(&b[seg..j]).into_owned()));
            }
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // Plain string literal.
        if c == b'"' {
            let j = skip_string(b, i);
            line += out[i..j].iter().filter(|&&x| x == b'\n').count();
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // Raw / byte string prefixes (word-boundary guarded so identifiers
        // like `br_x` or raw idents like `r#fn` pass through untouched).
        let at_word_start = i == 0 || !is_word_byte(b[i - 1]);
        if at_word_start && (c == b'r' || c == b'b') {
            let (prefix_len, byte_str) = match (c, b.get(i + 1)) {
                (b'b', Some(b'r')) => (2, false),
                (b'b', Some(b'"')) => (1, true),
                (b'b', Some(b'\'')) => {
                    if let Some(j) = skip_char_literal(b, i + 1, true) {
                        blank(&mut out, i, j);
                        i = j;
                        continue;
                    }
                    (0, false)
                }
                (b'r', _) => (1, false),
                _ => (0, false),
            };
            if byte_str {
                let j = skip_string(b, i + 1);
                line += out[i..j].iter().filter(|&&x| x == b'\n').count();
                blank(&mut out, i, j);
                i = j;
                continue;
            }
            if prefix_len > 0 {
                if let Some(j) = skip_raw_string(b, i + prefix_len) {
                    line += out[i..j].iter().filter(|&&x| x == b'\n').count();
                    blank(&mut out, i, j);
                    i = j;
                    continue;
                }
            }
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            if let Some(j) = skip_char_literal(b, i, false) {
                blank(&mut out, i, j);
                i = j;
                continue;
            }
        }
        i += 1;
    }
    (out, comments)
}

fn line_starts_of(src: &[u8]) -> Vec<usize> {
    let mut starts = vec![0usize];
    for (i, &b) in src.iter().enumerate() {
        if b == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

fn line_of(line_starts: &[usize], offset: usize) -> usize {
    match line_starts.binary_search(&offset) {
        Ok(i) => i + 1,
        Err(i) => i,
    }
}

/// Word-boundary occurrences of `needle` in `hay`. Needles may end in `!`
/// (macro tokens) or contain `::`; boundaries are checked on the needle's
/// outer bytes.
fn find_word(hay: &[u8], needle: &str) -> Vec<usize> {
    let n = needle.as_bytes();
    let mut hits = Vec::new();
    if n.is_empty() || hay.len() < n.len() {
        return hits;
    }
    let mut i = 0usize;
    while i + n.len() <= hay.len() {
        if &hay[i..i + n.len()] == n
            && (i == 0 || !is_word_byte(hay[i - 1]))
            && (i + n.len() == hay.len() || !is_word_byte(hay[i + n.len()]))
        {
            hits.push(i);
            i += n.len();
        } else {
            i += 1;
        }
    }
    hits
}

/// Plain substring occurrences (for attribute patterns).
fn find_substr(hay: &[u8], needle: &str) -> Vec<usize> {
    let n = needle.as_bytes();
    if n.is_empty() || hay.len() < n.len() {
        return Vec::new();
    }
    hay.windows(n.len()).enumerate().filter(|(_, w)| *w == n).map(|(i, _)| i).collect()
}

/// True when, skipping whitespace backwards from `pos`, the previous word
/// token is exactly `kw`.
fn preceded_by_kw(masked: &[u8], pos: usize, kw: &str) -> bool {
    let k = kw.as_bytes();
    let mut j = pos;
    while j > 0 && masked[j - 1].is_ascii_whitespace() {
        j -= 1;
    }
    j >= k.len()
        && &masked[j - k.len()..j] == k
        && (j == k.len() || !is_word_byte(masked[j - k.len() - 1]))
}

/// Byte ranges of `#[cfg(test)]` / `#[test]` items: from the attribute to
/// the end of the following brace block (or `;` for gated declarations).
fn test_ranges_of(masked: &[u8]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    for pat in ["#[cfg(test)]", "#[test]"] {
        for start in find_substr(masked, pat) {
            let mut j = start + pat.len();
            // Skip whitespace and any further attributes on the same item.
            loop {
                while j < masked.len() && masked[j].is_ascii_whitespace() {
                    j += 1;
                }
                if j < masked.len() && masked[j] == b'#' {
                    let mut bdepth = 0i32;
                    while j < masked.len() {
                        match masked[j] {
                            b'[' => bdepth += 1,
                            b']' => {
                                bdepth -= 1;
                                if bdepth == 0 {
                                    j += 1;
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                } else {
                    break;
                }
            }
            let mut depth = 0i32;
            let mut end = masked.len();
            while j < masked.len() {
                match masked[j] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            end = j + 1;
                            break;
                        }
                    }
                    b';' if depth == 0 => {
                        end = j + 1;
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            ranges.push((start, end));
        }
    }
    ranges
}

fn fn_items_of(masked: &[u8]) -> Vec<FnItem> {
    let mut items = Vec::new();
    for pos in find_word(masked, "fn") {
        let mut j = pos + 2;
        while j < masked.len() && masked[j].is_ascii_whitespace() {
            j += 1;
        }
        let name_start = j;
        while j < masked.len() && is_word_byte(masked[j]) {
            j += 1;
        }
        if j == name_start {
            continue; // fn-pointer type like `fn(u32) -> u32`, not an item
        }
        let name = String::from_utf8_lossy(&masked[name_start..j]).into_owned();
        let mut paren = 0i32;
        let mut body = None;
        let mut decl_end = masked.len();
        let mut k = j;
        while k < masked.len() {
            match masked[k] {
                b'(' => paren += 1,
                b')' => paren -= 1,
                b'{' if paren == 0 => {
                    let mut depth = 0i32;
                    let mut end = masked.len();
                    let mut m = k;
                    while m < masked.len() {
                        match masked[m] {
                            b'{' => depth += 1,
                            b'}' => {
                                depth -= 1;
                                if depth == 0 {
                                    end = m + 1;
                                    break;
                                }
                            }
                            _ => {}
                        }
                        m += 1;
                    }
                    body = Some((k, end));
                    decl_end = k;
                    break;
                }
                b';' if paren == 0 => {
                    decl_end = k;
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        items.push(FnItem { name, name_pos: name_start, decl_end, body });
    }
    items
}

fn analyze(rel: &str, src: &str) -> SourceFile {
    let (masked, comments) = mask(src);
    let line_starts = line_starts_of(src.as_bytes());
    let test_ranges = test_ranges_of(&masked);
    let fns = fn_items_of(&masked);
    SourceFile { rel: rel.to_string(), masked, comments, line_starts, test_ranges, fns }
}

impl SourceFile {
    fn line_of(&self, offset: usize) -> usize {
        line_of(&self.line_starts, offset)
    }

    fn in_test(&self, offset: usize) -> bool {
        self.test_ranges.iter().any(|&(a, b)| offset >= a && offset < b)
    }

    /// `tidy-allow: <rule>` on the given line or the line directly above.
    fn allowed(&self, line: usize, rule: &str) -> bool {
        let pat = format!("tidy-allow: {rule}");
        self.comments
            .iter()
            .any(|(l, text)| (*l == line || *l + 1 == line) && text.contains(&pat))
    }

    /// A `SAFETY:` comment on the line or within the two lines above it.
    fn has_safety_comment(&self, line: usize) -> bool {
        self.comments
            .iter()
            .any(|(l, text)| *l <= line && *l + 2 >= line && text.contains("SAFETY:"))
    }

    /// Name of the innermost fn whose body contains `offset`.
    fn enclosing_fn(&self, offset: usize) -> Option<&str> {
        self.fns
            .iter()
            .filter(|f| f.body.is_some_and(|(a, b)| offset >= a && offset < b))
            .min_by_key(|f| {
                let (a, b) = f.body.unwrap_or((0, usize::MAX));
                b - a
            })
            .map(|f| f.name.as_str())
    }
}

// ---------------------------------------------------------------------------
// Rule 1: hot-path-alloc
// ---------------------------------------------------------------------------

fn hot_alloc_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/quant/")
        || rel.starts_with("rust/src/hw/")
        || rel.starts_with("rust/src/rng/")
        || rel == "rust/src/coordinator/layer_step.rs"
}

fn rule_hot_alloc(files: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for file in files.iter().filter(|f| hot_alloc_scope(&f.rel)) {
        for f in &file.fns {
            let hot = f.name.ends_with("_into") || f.name.ends_with("_scratch");
            let Some((body_start, body_end)) = f.body else { continue };
            if !hot || file.in_test(f.name_pos) {
                continue;
            }
            for token in ALLOC_TOKENS {
                for hit in find_word(&file.masked[body_start..body_end], token) {
                    let line = file.line_of(body_start + hit);
                    if file.allowed(line, "hot-path-alloc") {
                        continue;
                    }
                    out.push(Violation {
                        file: file.rel.clone(),
                        line,
                        rule: "hot-path-alloc",
                        msg: format!("`{token}` in hot-path fn `{}`", f.name),
                        hint: HINT_HOT_ALLOC,
                    });
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 2: rng-registry
// ---------------------------------------------------------------------------

fn rng_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/")
        && !rel.starts_with("rust/src/rng/")
        && !rel.starts_with("rust/src/testutil/")
}

/// Draw sites found in the tree: registry key -> first line observed.
fn collect_draw_sites(files: &[SourceFile]) -> BTreeMap<String, usize> {
    let mut sites = BTreeMap::new();
    for file in files.iter().filter(|f| rng_scope(&f.rel)) {
        for token in DRAW_TOKENS {
            for hit in find_word(&file.masked, token) {
                if file.in_test(hit) || preceded_by_kw(&file.masked, hit, "fn") {
                    continue;
                }
                let line = file.line_of(hit);
                if file.allowed(line, "rng-registry") {
                    continue;
                }
                let who = file.enclosing_fn(hit).unwrap_or("<module>");
                let key = format!("{} {} {}", file.rel, who, token);
                sites.entry(key).or_insert(line);
            }
        }
    }
    sites
}

fn rule_rng_registry(
    files: &[SourceFile],
    registry: &BTreeSet<String>,
) -> (Vec<Violation>, Vec<String>) {
    let sites = collect_draw_sites(files);
    let mut violations = Vec::new();
    let mut notices = Vec::new();
    for (key, line) in &sites {
        if !registry.contains(key) {
            let rel = key.split(' ').next().unwrap_or("");
            violations.push(Violation {
                file: rel.to_string(),
                line: *line,
                rule: "rng-registry",
                msg: format!("unregistered RNG draw site; add to {REGISTRY_PATH}: `{key}`"),
                hint: HINT_RNG,
            });
        }
    }
    let scanned: BTreeSet<&str> = files.iter().map(|f| f.rel.as_str()).collect();
    for entry in registry {
        let rel = entry.split(' ').next().unwrap_or("");
        if scanned.contains(rel) && !sites.contains_key(entry) {
            notices.push(format!(
                "{REGISTRY_PATH}: stale entry `{entry}` (site no longer present; prune it)"
            ));
        }
    }
    (violations, notices)
}

// ---------------------------------------------------------------------------
// Rule 3: coverage
// ---------------------------------------------------------------------------

/// Variant names and definition lines of `enum <name>` in `file`.
fn enum_variants(file: &SourceFile, enum_name: &str) -> Vec<(String, usize)> {
    let masked = &file.masked;
    for pos in find_word(masked, enum_name) {
        if !preceded_by_kw(masked, pos, "enum") {
            continue;
        }
        let mut k = pos;
        while k < masked.len() && masked[k] != b'{' {
            k += 1;
        }
        let mut depth = 0i32;
        let mut expecting = true;
        let mut out = Vec::new();
        while k < masked.len() {
            match masked[k] {
                b'{' | b'(' | b'[' => depth += 1,
                b'}' | b')' | b']' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                b',' if depth == 1 => expecting = true,
                b'#' if depth == 1 => {
                    // Skip an attribute wholesale.
                    let mut bdepth = 0i32;
                    while k < masked.len() {
                        match masked[k] {
                            b'[' => bdepth += 1,
                            b']' => {
                                bdepth -= 1;
                                if bdepth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        k += 1;
                    }
                }
                c if depth == 1 && expecting && is_word_byte(c) => {
                    let start = k;
                    while k < masked.len() && is_word_byte(masked[k]) {
                        k += 1;
                    }
                    let name = String::from_utf8_lossy(&masked[start..k]).into_owned();
                    out.push((name, file.line_of(start)));
                    expecting = false;
                    continue;
                }
                _ => {}
            }
            k += 1;
        }
        return out;
    }
    Vec::new()
}

/// Fns in `file` whose signature returns `&'static ProductLut`.
fn lut_accessors(file: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for f in &file.fns {
        let sig = &file.masked[f.name_pos..f.decl_end.min(file.masked.len())];
        if String::from_utf8_lossy(sig).contains("&'static ProductLut") {
            out.push((f.name.clone(), file.line_of(f.name_pos)));
        }
    }
    out
}

/// Fns in `file` whose signature returns `ShardConfig` — the K-sharding
/// constructors. Every way to build a shard configuration must be
/// exercised by the conformance harness, the benches, and the fault
/// suite, so no tier-2 entry point escapes the contract tests.
fn shard_constructors(file: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for f in &file.fns {
        let sig = &file.masked[f.name_pos..f.decl_end.min(file.masked.len())];
        if String::from_utf8_lossy(sig).contains("-> ShardConfig") {
            out.push((f.name.clone(), file.line_of(f.name_pos)));
        }
    }
    out
}

/// Fns in `file` whose signature returns `StepProfile` or
/// `Result<StepProfile, _>` — the session-profile constructors. Every way
/// to build a [`StepProfile`] (paper defaults, the builder, TOML) must be
/// exercised by the conformance harness, the benches, and the fault suite:
/// the profile is the serve/config/trainer session contract, so an
/// unexercised constructor is an untested entry point into every layer
/// above the kernels.
fn profile_constructors(file: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for f in &file.fns {
        let sig = &file.masked[f.name_pos..f.decl_end.min(file.masked.len())];
        let sig = String::from_utf8_lossy(sig);
        // `-> StepProfileBuilder` also contains `-> StepProfile`; the
        // builder itself is not a profile constructor (its `build` is).
        if (sig.contains("-> StepProfile") && !sig.contains("-> StepProfileBuilder"))
            || sig.contains("-> Result<StepProfile")
        {
            out.push((f.name.clone(), file.line_of(f.name_pos)));
        }
    }
    out
}

fn rule_coverage(files: &[SourceFile]) -> Vec<Violation> {
    let by_rel = |rel: &str| files.iter().find(|f| f.rel == rel);
    let conformance = by_rel("rust/src/testutil/conformance.rs");
    let fault = by_rel("rust/src/testutil/fault_suite.rs");
    let benches: Vec<&SourceFile> =
        files.iter().filter(|f| f.rel.starts_with("benches/")).collect();

    let referenced = |name: &str, corpus: Option<&SourceFile>| {
        corpus.is_some_and(|f| !find_word(&f.masked, name).is_empty())
    };
    let referenced_in_benches =
        |name: &str| benches.iter().any(|f| !find_word(&f.masked, name).is_empty());

    let mut required = Vec::new();
    if let Some(def) = by_rel("rust/src/coordinator/layer_step.rs") {
        for (v, line) in enum_variants(def, "ForwardFormat") {
            required.push((def, v, line, "ForwardFormat variant", true));
        }
    }
    if let Some(def) = by_rel("rust/src/hw/qgemm.rs") {
        for (v, line) in lut_accessors(def) {
            required.push((def, v, line, "ProductLut instantiation", true));
        }
        for (v, line) in enum_variants(def, "KernelPath") {
            required.push((def, v, line, "KernelPath variant", true));
        }
        for (v, line) in shard_constructors(def) {
            required.push((def, v, line, "ShardConfig constructor", true));
        }
    }
    if let Some(def) = by_rel("rust/src/coordinator/profile.rs") {
        for (v, line) in profile_constructors(def) {
            required.push((def, v, line, "StepProfile constructor", true));
        }
    }
    if let Some(def) = by_rel("rust/src/quant/health.rs") {
        for (v, line) in enum_variants(def, "FaultClass") {
            required.push((def, v, line, "FaultClass variant", false));
        }
    }

    let mut out = Vec::new();
    for (def, name, line, kind, everywhere) in required {
        if def.allowed(line, "coverage") {
            continue;
        }
        let mut missing: Vec<&str> = Vec::new();
        if everywhere && !referenced(&name, conformance) {
            missing.push("testutil/conformance.rs");
        }
        if everywhere && !referenced_in_benches(&name) {
            missing.push("benches/*.rs");
        }
        if !referenced(&name, fault) {
            missing.push("testutil/fault_suite.rs");
        }
        if !missing.is_empty() {
            out.push(Violation {
                file: def.rel.clone(),
                line,
                rule: "coverage",
                msg: format!("{kind} `{name}` is not referenced in: {}", missing.join(", ")),
                hint: HINT_COVERAGE,
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 4: panic-policy
// ---------------------------------------------------------------------------

fn panic_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/")
        && rel != "rust/src/main.rs"
        && !rel.starts_with("rust/src/bin/")
        && !rel.starts_with("rust/src/testutil/")
}

/// Whether the next non-whitespace byte after `pos` is `(` — distinguishes
/// `.unwrap()` calls from identifiers merely containing the word.
fn followed_by_call(masked: &[u8], pos: usize) -> bool {
    let mut j = pos;
    while j < masked.len() && masked[j].is_ascii_whitespace() {
        j += 1;
    }
    j < masked.len() && masked[j] == b'('
}

/// `file:line: token` for every counted panic site, sorted.
fn collect_panic_sites(files: &[SourceFile]) -> Vec<String> {
    let mut sites = Vec::new();
    for file in files.iter().filter(|f| panic_scope(&f.rel)) {
        for token in ["unwrap", "expect", "panic!", "unreachable!"] {
            for hit in find_word(&file.masked, token) {
                if file.in_test(hit) || preceded_by_kw(&file.masked, hit, "fn") {
                    continue;
                }
                let is_method = !token.ends_with('!');
                if is_method && !followed_by_call(&file.masked, hit + token.len()) {
                    continue;
                }
                let line = file.line_of(hit);
                if file.allowed(line, "panic-policy") {
                    continue;
                }
                sites.push((file.rel.clone(), line, token));
            }
        }
    }
    sites.sort();
    sites.into_iter().map(|(rel, line, token)| format!("{rel}:{line}: `{token}`")).collect()
}

fn rule_panic(
    files: &[SourceFile],
    budget: Option<usize>,
) -> (Vec<Violation>, Vec<String>, Vec<String>) {
    let sites = collect_panic_sites(files);
    let mut violations = Vec::new();
    let mut notices = Vec::new();
    match budget {
        None => violations.push(Violation {
            file: BUDGET_PATH.to_string(),
            line: 1,
            rule: "panic-policy",
            msg: format!("missing or unreadable budget file ({} sites counted)", sites.len()),
            hint: HINT_PANIC,
        }),
        Some(b) if sites.len() > b => violations.push(Violation {
            file: BUDGET_PATH.to_string(),
            line: 1,
            rule: "panic-policy",
            msg: format!(
                "{} panic sites in non-test library code exceed the budget of {b} \
                 (the budget may only shrink)",
                sites.len()
            ),
            hint: HINT_PANIC,
        }),
        Some(b) if sites.len() < b => notices.push(format!(
            "{BUDGET_PATH}: budget {b} has slack — {} sites counted; lower it to lock in the \
             burn-down",
            sites.len()
        )),
        Some(_) => {}
    }
    (violations, notices, sites)
}

// ---------------------------------------------------------------------------
// Rule 5: safety-comment
// ---------------------------------------------------------------------------

fn rule_safety(files: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for file in files.iter().filter(|f| f.rel.starts_with("rust/src/")) {
        for hit in find_word(&file.masked, "unsafe") {
            let line = file.line_of(hit);
            if file.has_safety_comment(line) || file.allowed(line, "safety-comment") {
                continue;
            }
            out.push(Violation {
                file: file.rel.clone(),
                line,
                rule: "safety-comment",
                msg: "`unsafe` without a `// SAFETY:` comment".to_string(),
                hint: HINT_SAFETY,
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries = Vec::new();
    for entry in fs::read_dir(dir)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn load_tree(root: &Path) -> std::io::Result<Vec<SourceFile>> {
    let mut paths = Vec::new();
    walk_rs(&root.join("rust/src"), &mut paths)?;
    let benches = root.join("benches");
    if benches.is_dir() {
        walk_rs(&benches, &mut paths)?;
    }
    let mut files = Vec::new();
    for path in paths {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let src = fs::read_to_string(&path)?;
        files.push(analyze(&rel, &src));
    }
    Ok(files)
}

/// Registry lines, `#` comments and blanks stripped.
fn parse_registry(text: &str) -> BTreeSet<String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

/// First integer line of the budget file.
fn parse_budget(text: &str) -> Option<usize> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .find_map(|l| l.parse().ok())
}

struct Report {
    violations: Vec<Violation>,
    notices: Vec<String>,
    panic_sites: Vec<String>,
    n_files: usize,
}

fn run_all(root: &Path) -> std::io::Result<Report> {
    let files = load_tree(root)?;
    let registry = fs::read_to_string(root.join(REGISTRY_PATH))
        .map(|t| parse_registry(&t))
        .unwrap_or_default();
    let budget = fs::read_to_string(root.join(BUDGET_PATH)).ok().and_then(|t| parse_budget(&t));

    let mut violations = Vec::new();
    let mut notices = Vec::new();
    violations.extend(rule_hot_alloc(&files));
    let (v, n) = rule_rng_registry(&files, &registry);
    violations.extend(v);
    notices.extend(n);
    violations.extend(rule_coverage(&files));
    let (v, n, panic_sites) = rule_panic(&files, budget);
    let panic_failed = !v.is_empty();
    violations.extend(v);
    notices.extend(n);
    violations.extend(rule_safety(&files));

    violations.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    let sites = if panic_failed { panic_sites } else { Vec::new() };
    Ok(Report { violations, notices, panic_sites: sites, n_files: files.len() })
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("tidy: --root requires a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("usage: tidy [--root <repo-root>]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("tidy: unknown flag `{other}` (known: --root <path>)");
                return ExitCode::from(2);
            }
        }
    }
    if !root.join("rust/src").is_dir() {
        let shown = root.display();
        eprintln!("tidy: {shown} has no rust/src — run from the repo root or pass --root");
        return ExitCode::from(2);
    }
    let report = match run_all(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("tidy: io error: {e}");
            return ExitCode::from(2);
        }
    };
    for v in &report.violations {
        println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
        println!("  fix: {}", v.hint);
    }
    if !report.panic_sites.is_empty() {
        println!("panic-policy sites counted:");
        for site in &report.panic_sites {
            println!("  {site}");
        }
    }
    for n in &report.notices {
        println!("note: {n}");
    }
    if report.violations.is_empty() {
        println!("tidy: clean ({} files, 5 rules)", report.n_files);
        ExitCode::SUCCESS
    } else {
        println!("tidy: {} violation(s)", report.violations.len());
        ExitCode::FAILURE
    }
}

// ---------------------------------------------------------------------------
// Self-tests: fixtures per rule (violating / clean / exempted) plus the
// scanner primitives and a repo-clean integration check. All names start
// with `tidy_` so `cargo test -q tidy_` runs exactly this suite.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn file(rel: &str, src: &str) -> SourceFile {
        analyze(rel, src)
    }

    #[test]
    fn tidy_mask_blanks_strings_comments_and_chars() {
        let src = "let s = \"vec![no]\"; // vec! in comment\nlet c = '\"'; let v = vec![1];\n";
        let (masked, comments) = mask(src);
        let m = String::from_utf8_lossy(&masked).into_owned();
        assert!(!m.contains("no"), "string not blanked: {m}");
        assert!(!m.contains("comment"), "comment not blanked: {m}");
        assert_eq!(find_word(masked.as_slice(), "vec!").len(), 1, "{m}");
        assert_eq!(comments.len(), 1);
        assert_eq!(comments[0].0, 1);
        assert!(comments[0].1.contains("vec! in comment"));
    }

    #[test]
    fn tidy_mask_handles_raw_strings_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) -> &'static str { r#\"clone()\"# ; b\"to_vec\"; \"x\" }\n";
        let (masked, _) = mask(src);
        let m = String::from_utf8_lossy(&masked).into_owned();
        assert!(m.contains("'a"), "lifetime was eaten: {m}");
        assert!(m.contains("'static"), "'static was eaten: {m}");
        assert!(find_word(&masked, "clone").is_empty(), "raw string not blanked: {m}");
        assert!(find_word(&masked, "to_vec").is_empty(), "byte string not blanked: {m}");
    }

    #[test]
    fn tidy_mask_handles_nested_block_comments_and_escapes() {
        let src = "/* outer /* inner clone */ still */ let x = \"a\\\"clone\\\"b\";\nlet y = 1;\n";
        let (masked, _) = mask(src);
        assert!(find_word(&masked, "clone").is_empty());
        assert_eq!(find_word(&masked, "y").len(), 1);
    }

    #[test]
    fn tidy_fn_extraction_finds_bodies_and_enclosing_fn() {
        let src = "pub fn alpha(x: u32) -> u32 {\n    let v = x;\n    v\n}\nfn beta();\n";
        let f = file("rust/src/quant/x.rs", src);
        assert_eq!(f.fns.len(), 2);
        assert_eq!(f.fns[0].name, "alpha");
        assert!(f.fns[0].body.is_some());
        assert_eq!(f.fns[1].name, "beta");
        assert!(f.fns[1].body.is_none());
        let off = src.find("let v").unwrap();
        assert_eq!(f.enclosing_fn(off), Some("alpha"));
    }

    #[test]
    fn tidy_test_region_detection() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {}\n}\n";
        let f = file("rust/src/quant/x.rs", src);
        let live = src.find("live").unwrap();
        let t = src.find("fn t").unwrap();
        assert!(!f.in_test(live));
        assert!(f.in_test(t));
    }

    const HOT_VIOLATING: &str =
        "pub fn quantize_into(out: &mut [f32]) {\n    let v = vec![0.0f32; 4];\n    out[0] = v[0];\n}\n";
    const HOT_CLEAN: &str =
        "pub fn quantize_into(out: &mut [f32]) {\n    for o in out.iter_mut() {\n        *o = 0.0;\n    }\n}\n";
    const HOT_EXEMPT: &str = "pub fn quantize_into(out: &mut [f32]) {\n    \
         // tidy-allow: hot-path-alloc (cold setup path, measured once)\n    \
         let v = vec![0.0f32; 4];\n    out[0] = v[0];\n}\n";

    #[test]
    fn tidy_hot_alloc_flags_vec_in_into_fn() {
        let files = vec![file("rust/src/quant/x.rs", HOT_VIOLATING)];
        let v = rule_hot_alloc(&files);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "hot-path-alloc");
        assert_eq!(v[0].line, 2);
        assert!(v[0].msg.contains("quantize_into"));
    }

    #[test]
    fn tidy_hot_alloc_clean_fn_passes() {
        let files = vec![file("rust/src/quant/x.rs", HOT_CLEAN)];
        assert!(rule_hot_alloc(&files).is_empty());
    }

    #[test]
    fn tidy_hot_alloc_allow_exempts() {
        let files = vec![file("rust/src/quant/x.rs", HOT_EXEMPT)];
        assert!(rule_hot_alloc(&files).is_empty());
    }

    #[test]
    fn tidy_hot_alloc_ignores_other_dirs_and_tests() {
        // Same violating code outside the hot-path scope: clean.
        let files = vec![file("rust/src/metrics/x.rs", HOT_VIOLATING)];
        assert!(rule_hot_alloc(&files).is_empty());
        // Inside a #[cfg(test)] block: clean.
        let src = format!("#[cfg(test)]\nmod tests {{\n{HOT_VIOLATING}\n}}\n");
        let files = vec![file("rust/src/quant/x.rs", &src)];
        assert!(rule_hot_alloc(&files).is_empty());
    }

    const DRAW_SITE: &str =
        "pub fn refill(rng: &mut Xoshiro256, out: &mut [f32]) {\n    rng.fill_uniform(out);\n}\n";

    #[test]
    fn tidy_rng_registry_flags_unregistered() {
        let files = vec![file("rust/src/quant/x.rs", DRAW_SITE)];
        let (v, _) = rule_rng_registry(&files, &BTreeSet::new());
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("rust/src/quant/x.rs refill fill_uniform"), "{}", v[0].msg);
    }

    #[test]
    fn tidy_rng_registry_registered_passes_and_stale_notices() {
        let files = vec![file("rust/src/quant/x.rs", DRAW_SITE)];
        let mut reg = BTreeSet::new();
        reg.insert("rust/src/quant/x.rs refill fill_uniform".to_string());
        reg.insert("rust/src/quant/x.rs gone next_u64".to_string());
        let (v, notices) = rule_rng_registry(&files, &reg);
        assert!(v.is_empty(), "{v:?}");
        assert_eq!(notices.len(), 1);
        assert!(notices[0].contains("stale"));
    }

    #[test]
    fn tidy_rng_registry_allow_and_scope_exempt() {
        let exempt = "pub fn refill(rng: &mut X, out: &mut [f32]) {\n    \
             // tidy-allow: rng-registry (draw count asserted locally)\n    \
             rng.fill_uniform(out);\n}\n";
        let files = vec![
            file("rust/src/quant/x.rs", exempt),
            // rng/ and testutil/ are out of scope entirely.
            file("rust/src/rng/x.rs", DRAW_SITE),
            file("rust/src/testutil/x.rs", DRAW_SITE),
        ];
        let (v, _) = rule_rng_registry(&files, &BTreeSet::new());
        assert!(v.is_empty(), "{v:?}");
    }

    /// A minimal multi-file tree for the coverage rule.
    fn coverage_tree(conf: &str, bench: &str, fault: &str) -> Vec<SourceFile> {
        let defs = "pub enum ForwardFormat {\n    Sawb,\n    Radix4Tpr,\n}\n";
        let health = "pub enum FaultClass {\n    NonFinite,\n}\n";
        let luts = "pub fn product_lut() -> &'static ProductLut {\n    &LUT\n}\n\
             pub enum KernelPath {\n    Scalar,\n    Portable,\n    Avx2,\n}\n\
             pub fn single() -> ShardConfig {\n    ShardConfig { n_shards: 1 }\n}\n";
        // `builder` returns the builder, not a profile — it must NOT be
        // picked up as a StepProfile constructor (its `build` is).
        let profile = "pub fn paper_default() -> StepProfile {\n    todo()\n}\n\
             pub fn builder() -> StepProfileBuilder {\n    todo()\n}\n\
             pub fn build(self) -> Result<StepProfile, String> {\n    todo()\n}\n\
             pub fn from_toml_section(t: &T) -> Result<StepProfile, String> {\n    todo()\n}\n";
        vec![
            file("rust/src/coordinator/layer_step.rs", defs),
            file("rust/src/quant/health.rs", health),
            file("rust/src/hw/qgemm.rs", luts),
            file("rust/src/coordinator/profile.rs", profile),
            file("rust/src/testutil/conformance.rs", conf),
            file("benches/qgemm.rs", bench),
            file("rust/src/testutil/fault_suite.rs", fault),
        ]
    }

    #[test]
    fn tidy_coverage_flags_unreferenced_variant() {
        let all = "fn f() { let _ = (Sawb, Radix4Tpr, product_lut, NonFinite, \
             Scalar, Portable, Avx2, single, paper_default, build, from_toml_section); }\n";
        let missing_radix = "fn f() { let _ = (Sawb, product_lut, NonFinite, \
             Scalar, Portable, Avx2, single, paper_default, build, from_toml_section); }\n";
        let v = rule_coverage(&coverage_tree(all, all, missing_radix));
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("Radix4Tpr"), "{}", v[0].msg);
        assert!(v[0].msg.contains("fault_suite"), "{}", v[0].msg);
    }

    #[test]
    fn tidy_coverage_flags_unreferenced_kernel_path() {
        let all = "fn f() { let _ = (Sawb, Radix4Tpr, product_lut, NonFinite, \
             Scalar, Portable, Avx2, single, paper_default, build, from_toml_section); }\n";
        let missing_avx2 = "fn f() { let _ = (Sawb, Radix4Tpr, product_lut, NonFinite, \
             Scalar, Portable, single, paper_default, build, from_toml_section); }\n";
        let v = rule_coverage(&coverage_tree(all, missing_avx2, all));
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("KernelPath variant `Avx2`"), "{}", v[0].msg);
        assert!(v[0].msg.contains("benches"), "{}", v[0].msg);
    }

    #[test]
    fn tidy_coverage_flags_unreferenced_shard_constructor() {
        let all = "fn f() { let _ = (Sawb, Radix4Tpr, product_lut, NonFinite, \
             Scalar, Portable, Avx2, single, paper_default, build, from_toml_section); }\n";
        let missing_single = "fn f() { let _ = (Sawb, Radix4Tpr, product_lut, NonFinite, \
             Scalar, Portable, Avx2, paper_default, build, from_toml_section); }\n";
        let v = rule_coverage(&coverage_tree(missing_single, all, all));
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("ShardConfig constructor `single`"), "{}", v[0].msg);
        assert!(v[0].msg.contains("conformance"), "{}", v[0].msg);
    }

    #[test]
    fn tidy_coverage_passes_when_referenced() {
        let all = "fn f() { let _ = (Sawb, Radix4Tpr, product_lut, NonFinite, \
             Scalar, Portable, Avx2, single, paper_default, build, from_toml_section); }\n";
        assert!(rule_coverage(&coverage_tree(all, all, all)).is_empty());
    }

    #[test]
    fn tidy_coverage_flags_unreferenced_profile_constructor() {
        // `builder` returns StepProfileBuilder and must not be required;
        // `from_toml_section` missing from the bench ladder must be.
        let all = "fn f() { let _ = (Sawb, Radix4Tpr, product_lut, NonFinite, \
             Scalar, Portable, Avx2, single, paper_default, build, from_toml_section); }\n";
        let missing_toml = "fn f() { let _ = (Sawb, Radix4Tpr, product_lut, NonFinite, \
             Scalar, Portable, Avx2, single, paper_default, build); }\n";
        let v = rule_coverage(&coverage_tree(all, missing_toml, all));
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("StepProfile constructor `from_toml_section`"), "{}", v[0].msg);
        assert!(v[0].msg.contains("benches"), "{}", v[0].msg);
        assert!(!v.iter().any(|x| x.msg.contains("`builder`")), "{v:?}");
    }

    #[test]
    fn tidy_coverage_allow_exempts_at_definition() {
        let defs = "pub enum ForwardFormat {\n    Sawb,\n    \
             // tidy-allow: coverage (format still landing)\n    Radix4Tpr,\n}\n";
        let rest = "fn f() { let _ = (Sawb, product_lut, NonFinite, \
             Scalar, Portable, Avx2, single, paper_default, build, from_toml_section); }\n";
        let mut files = coverage_tree(rest, rest, rest);
        files[0] = file("rust/src/coordinator/layer_step.rs", defs);
        assert!(rule_coverage(&files).is_empty());
    }

    const PANIC_SITE: &str = "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";

    #[test]
    fn tidy_panic_ratchet_over_budget_fails() {
        let files = vec![file("rust/src/quant/x.rs", PANIC_SITE)];
        let (v, _, sites) = rule_panic(&files, Some(0));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(sites.len(), 1);
        assert_eq!(sites[0], "rust/src/quant/x.rs:2: `unwrap`");
    }

    #[test]
    fn tidy_panic_ratchet_at_budget_passes_and_slack_notices() {
        let files = vec![file("rust/src/quant/x.rs", PANIC_SITE)];
        let (v, notices, _) = rule_panic(&files, Some(1));
        assert!(v.is_empty(), "{v:?}");
        assert!(notices.is_empty());
        let (v, notices, _) = rule_panic(&files, Some(5));
        assert!(v.is_empty(), "{v:?}");
        assert_eq!(notices.len(), 1, "{notices:?}");
        assert!(notices[0].contains("slack"));
    }

    #[test]
    fn tidy_panic_ignores_tests_allows_and_non_calls() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    \
             // tidy-allow: panic-policy (invariant: x checked above)\n    \
             x.unwrap()\n}\npub fn g(x: Option<u32>) -> u32 {\n    x.unwrap_or(0)\n}\n\
             #[cfg(test)]\nmod tests {\n    fn t() {\n        panic!();\n    }\n}\n";
        let files = vec![file("rust/src/quant/x.rs", src)];
        let (_, _, sites) = rule_panic(&files, Some(0));
        assert!(sites.is_empty(), "{sites:?}");
        // main.rs, bin/ and testutil/ are out of scope.
        let files = vec![
            file("rust/src/main.rs", PANIC_SITE),
            file("rust/src/bin/tidy.rs", PANIC_SITE),
            file("rust/src/testutil/x.rs", PANIC_SITE),
        ];
        let (_, _, sites) = rule_panic(&files, Some(0));
        assert!(sites.is_empty(), "{sites:?}");
    }

    #[test]
    fn tidy_panic_missing_budget_is_a_violation() {
        let files = vec![file("rust/src/quant/x.rs", PANIC_SITE)];
        let (v, _, _) = rule_panic(&files, None);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("missing"));
    }

    #[test]
    fn tidy_safety_requires_comment() {
        let bad = "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let files = vec![file("rust/src/hw/x.rs", bad)];
        let v = rule_safety(&files);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn tidy_safety_comment_or_allow_passes() {
        let good = "pub fn f(p: *const u8) -> u8 {\n    \
             // SAFETY: caller guarantees p is valid for reads\n    unsafe { *p }\n}\n";
        assert!(rule_safety(&[file("rust/src/hw/x.rs", good)]).is_empty());
        let waived = "pub fn f(p: *const u8) -> u8 {\n    \
             // tidy-allow: safety-comment (documented at the call site)\n    unsafe { *p }\n}\n";
        assert!(rule_safety(&[file("rust/src/hw/x.rs", waived)]).is_empty());
    }

    #[test]
    fn tidy_registry_and_budget_parsers() {
        let reg = parse_registry("# header\n\n a b c \nd e f\n");
        assert_eq!(reg.len(), 2);
        assert!(reg.contains("a b c"));
        assert_eq!(parse_budget("# why\n 42 \n"), Some(42));
        assert_eq!(parse_budget("# only comments\n"), None);
    }

    /// The whole tree must be clean: zero unexempted violations against the
    /// committed registry and budget. This is the same run CI performs.
    #[test]
    fn tidy_repo_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = run_all(root).expect("repo tree readable");
        assert!(report.n_files > 20, "suspiciously few files: {}", report.n_files);
        let rendered: Vec<String> = report
            .violations
            .iter()
            .map(|v| format!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg))
            .collect();
        assert!(rendered.is_empty(), "tidy violations:\n{}", rendered.join("\n"));
    }
}
