//! In-repo micro-benchmark harness (the offline registry has no
//! criterion). Provides warmup, calibrated iteration counts, and robust
//! statistics (median + MAD), plus throughput reporting — the API surface
//! the `benches/*.rs` binaries are written against.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    /// Median absolute deviation — robust spread.
    pub mad: Duration,
    pub min: Duration,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn throughput_melems(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / self.median.as_secs_f64() / 1e6)
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput_melems() {
            Some(t) => format!("  {:>10.2} Melem/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12.3?} ±{:>10.3?}  (min {:>10.3?}, n={}){}",
            self.name, self.median, self.mad, self.min, self.iters, tp
        )
    }
}

/// Benchmark runner with criterion-like calibration.
pub struct Bencher {
    /// Target wall time for the measurement phase.
    pub target: Duration,
    pub warmup: Duration,
    /// Number of measured samples.
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            target: Duration::from_millis(600),
            warmup: Duration::from_millis(150),
            samples: 15,
        }
    }
}

impl Bencher {
    /// Fast profile for CI-ish runs (set `LUQ_BENCH_FAST=1`).
    pub fn from_env() -> Self {
        if std::env::var("LUQ_BENCH_FAST").is_ok() {
            Bencher {
                target: Duration::from_millis(120),
                warmup: Duration::from_millis(30),
                samples: 7,
            }
        } else {
            Bencher::default()
        }
    }

    /// Run `f` repeatedly; `f` should perform one logical operation and
    /// return something consumed by `black_box`.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup + calibration: find iters-per-sample so one sample is
        // ~target/samples.
        let warm_start = Instant::now();
        let mut calls = 0u64;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            calls += 1;
        }
        let per_call = self.warmup.as_secs_f64() / calls.max(1) as f64;
        let per_sample = (self.target.as_secs_f64() / self.samples as f64 / per_call)
            .ceil()
            .max(1.0) as u64;

        let mut samples: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..per_sample {
                black_box(f());
            }
            samples.push(t0.elapsed() / per_sample as u32);
        }
        samples.sort();
        let median = samples[samples.len() / 2];
        let mut devs: Vec<Duration> = samples
            .iter()
            .map(|&s| if s > median { s - median } else { median - s })
            .collect();
        devs.sort();
        BenchResult {
            name: name.to_string(),
            iters: per_sample * self.samples as u64,
            median,
            mad: devs[devs.len() / 2],
            min: samples[0],
            elements: None,
        }
    }

    /// Like [`bench`] but annotates elements/iter for throughput.
    pub fn bench_throughput<T>(
        &self,
        name: &str,
        elements: u64,
        f: impl FnMut() -> T,
    ) -> BenchResult {
        let mut r = self.bench(name, f);
        r.elements = Some(elements);
        r
    }
}

/// Print a bench group header like the criterion output.
// Bench banners belong on stdout with the rest of the harness output.
#[allow(clippy::print_stdout)]
pub fn group(title: &str) {
    println!("\n== {title} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_sane() {
        let b = Bencher {
            target: Duration::from_millis(40),
            warmup: Duration::from_millis(10),
            samples: 5,
        };
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i) * i);
            }
            acc
        });
        assert!(r.median > Duration::from_nanos(50));
        assert!(r.median < Duration::from_millis(10));
        assert!(r.iters >= 5);
    }

    #[test]
    fn throughput_annotation() {
        let b = Bencher {
            target: Duration::from_millis(20),
            warmup: Duration::from_millis(5),
            samples: 3,
        };
        let r = b.bench_throughput("tp", 1_000_000, || 1 + 1);
        assert!(r.throughput_melems().unwrap() > 0.0);
        assert!(r.report().contains("Melem/s"));
    }
}
