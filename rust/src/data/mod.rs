//! Synthetic-data substrate (DESIGN.md §4: ImageNet/WMT are hardware-gated,
//! so every experiment runs on synthetic workloads that exercise the same
//! code paths).
//!
//! * [`corpus`] — a Zipf-weighted Markov-chain token stream for the
//!   transformer LM experiments: non-trivial (learnable) structure, a
//!   heavy-tailed unigram distribution, and a held-out split.
//! * [`images`] — a Gaussian-mixture "mini-ImageNet": class templates in
//!   pixel space plus noise, linearly separable only in combination, for
//!   the CNN experiments.
//! * [`gradients`] — direct samplers of lognormal neural-gradient tensors
//!   (Chmiel et al. 2021's model) for quantizer-only experiments.

pub mod corpus;
pub mod gradients;
pub mod images;

pub use corpus::{CorpusConfig, TokenCorpus};
pub use images::{ImageDataset, ImagesConfig};
