//! Zipf-Markov synthetic token corpus.
//!
//! Construction: a random first-order Markov chain over `vocab` tokens
//! whose per-state transition distributions concentrate on a few
//! successors (temperature-controlled), with stationary mass shaped
//! towards Zipf. A transformer LM can drive its cross-entropy well below
//! the unigram entropy by learning the transitions, so loss curves are
//! informative — which is all the quantization experiments need.

use crate::rng::Xoshiro256;

#[derive(Clone, Copy, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// Successors per state with non-negligible probability.
    pub branching: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { vocab: 256, branching: 8, seed: 0xC0FFEE }
    }
}

/// A generative Markov corpus with train/eval streams.
pub struct TokenCorpus {
    cfg: CorpusConfig,
    /// transitions[s] = list of (successor, cumulative probability)
    transitions: Vec<Vec<(u32, f32)>>,
}

impl TokenCorpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
        let mut transitions = Vec::with_capacity(cfg.vocab);
        for _ in 0..cfg.vocab {
            // Pick `branching` successors with Zipf-ish weights 1/k.
            let mut succ: Vec<u32> = Vec::with_capacity(cfg.branching);
            while succ.len() < cfg.branching {
                let c = rng.uniform_usize(cfg.vocab) as u32;
                if !succ.contains(&c) {
                    succ.push(c);
                }
            }
            let weights: Vec<f32> = (1..=cfg.branching).map(|k| 1.0 / k as f32).collect();
            let z: f32 = weights.iter().sum();
            let mut acc = 0.0f32;
            let rows = succ
                .iter()
                .zip(weights.iter())
                .map(|(&s, &w)| {
                    acc += w / z;
                    (s, acc)
                })
                .collect();
            transitions.push(rows);
        }
        TokenCorpus { cfg, transitions }
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn step(&self, state: u32, rng: &mut Xoshiro256) -> u32 {
        let u = rng.uniform_f32();
        let rows = &self.transitions[state as usize];
        for &(s, cum) in rows {
            if u < cum {
                return s;
            }
        }
        rows.last().unwrap().0
    }

    /// Generate a `[batch, seq_len + 1]` token block (inputs || next-token
    /// targets come from adjacent positions). `stream_seed` selects a
    /// deterministic stream: use disjoint seeds for train vs eval.
    pub fn batch(&self, batch: usize, seq_len: usize, stream_seed: u64) -> Vec<u32> {
        let mut rng = Xoshiro256::seed_from_u64(stream_seed);
        let mut out = Vec::with_capacity(batch * (seq_len + 1));
        for _ in 0..batch {
            let mut state = rng.uniform_usize(self.cfg.vocab) as u32;
            out.push(state);
            for _ in 0..seq_len {
                state = self.step(state, &mut rng);
                out.push(state);
            }
        }
        out
    }

    /// The entropy rate (nats/token) of the chain under a uniform start —
    /// a lower bound any LM's loss can approach but not beat. Used by the
    /// e2e example to sanity-check the loss curve's floor.
    pub fn transition_entropy(&self) -> f64 {
        let mut h = 0.0f64;
        for rows in &self.transitions {
            let mut prev = 0.0f32;
            for &(_, cum) in rows {
                let p = (cum - prev) as f64;
                if p > 0.0 {
                    h -= p * p.ln();
                }
                prev = cum;
            }
        }
        h / self.transitions.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shape_and_range() {
        let c = TokenCorpus::new(CorpusConfig::default());
        let b = c.batch(4, 32, 1);
        assert_eq!(b.len(), 4 * 33);
        assert!(b.iter().all(|&t| (t as usize) < c.vocab()));
    }

    #[test]
    fn deterministic_per_stream_seed() {
        let c = TokenCorpus::new(CorpusConfig::default());
        assert_eq!(c.batch(2, 16, 7), c.batch(2, 16, 7));
        assert_ne!(c.batch(2, 16, 7), c.batch(2, 16, 8));
    }

    #[test]
    fn chain_is_learnable_structure_not_iid() {
        // Entropy rate must be far below log(vocab): structure exists.
        let c = TokenCorpus::new(CorpusConfig::default());
        let h = c.transition_entropy();
        let uniform = (c.vocab() as f64).ln();
        assert!(h < uniform * 0.5, "entropy rate {h} vs uniform {uniform}");
        assert!(h > 0.5, "chain should not be (near-)deterministic: {h}");
    }

    #[test]
    fn transitions_are_proper_distributions() {
        let c = TokenCorpus::new(CorpusConfig::default());
        for rows in &c.transitions {
            let last = rows.last().unwrap().1;
            assert!((last - 1.0).abs() < 1e-5, "cumsum ends at {last}");
        }
    }

    #[test]
    fn bigram_statistics_match_transition_matrix() {
        // Long-run sampled bigram frequencies should approximate the
        // designed transition probabilities.
        let cfg = CorpusConfig { vocab: 16, branching: 4, seed: 3 };
        let c = TokenCorpus::new(cfg);
        let toks = c.batch(1, 200_000, 11);
        let mut counts = vec![vec![0u32; 16]; 16];
        for w in toks.windows(2) {
            counts[w[0] as usize][w[1] as usize] += 1;
        }
        // Check one well-visited state.
        let s = toks[0] as usize;
        let total: u32 = counts[s].iter().sum();
        let mut prev = 0.0f32;
        for &(succ, cum) in &c.transitions[s] {
            let p_design = cum - prev;
            prev = cum;
            let p_emp = counts[s][succ as usize] as f32 / total as f32;
            assert!(
                (p_emp - p_design).abs() < 0.05,
                "state {s} -> {succ}: designed {p_design}, sampled {p_emp}"
            );
        }
    }
}
