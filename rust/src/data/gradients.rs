//! Direct samplers of synthetic neural-gradient tensors.
//!
//! Chmiel et al. (2021) — reference [9] of the paper — showed neural
//! gradients are well modelled as lognormal with layer-dependent σ (larger
//! σ deeper in backprop). Quantizer-only experiments (Fig. 1a, the MSE
//! sweeps, the throughput benches) sample from this model instead of
//! running backprop, which isolates the quantizer under the exact
//! distribution the paper designs for.

use crate::rng::Xoshiro256;

/// Parameters of the lognormal gradient model.
#[derive(Clone, Copy, Debug)]
pub struct GradientModel {
    pub mu: f32,
    pub sigma: f32,
    /// Fraction of exact zeros (ReLU backprop kills a large share).
    pub zero_fraction: f32,
}

impl Default for GradientModel {
    fn default() -> Self {
        // σ≈2 is mid-range for conv layers per [9]; ~50% zeros from ReLU.
        GradientModel { mu: 0.0, sigma: 2.0, zero_fraction: 0.5 }
    }
}

impl GradientModel {
    pub fn sample(&self, n: usize, rng: &mut Xoshiro256) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if rng.uniform_f32() < self.zero_fraction {
                    0.0
                } else {
                    rng.signed_lognormal_f32(self.mu, self.sigma)
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_fraction_respected() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let g = GradientModel { zero_fraction: 0.5, ..Default::default() };
        let xs = g.sample(100_000, &mut rng);
        let zf = xs.iter().filter(|&&v| v == 0.0).count() as f64 / xs.len() as f64;
        assert!((zf - 0.5).abs() < 0.01, "zero fraction {zf}");
    }

    #[test]
    fn log_magnitudes_are_normal() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let g = GradientModel { mu: 0.0, sigma: 2.0, zero_fraction: 0.0 };
        let xs = g.sample(100_000, &mut rng);
        let logs: Vec<f64> = xs.iter().map(|v| (v.abs() as f64).ln()).collect();
        let mean = logs.iter().sum::<f64>() / logs.len() as f64;
        let var = logs.iter().map(|l| (l - mean).powi(2)).sum::<f64>() / logs.len() as f64;
        assert!(mean.abs() < 0.05, "log-mean {mean}");
        assert!((var.sqrt() - 2.0).abs() < 0.05, "log-std {}", var.sqrt());
    }
}
