//! The PJRT engine: artifact loading, compile caching, validated
//! execution.
//!
//! Pattern from `/opt/xla-example/load_hlo/`: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Compilation happens once per artifact
//! per process (the cache below); the training loop only pays
//! literal-copy + execute per step.

use super::meta::ArtifactMeta;
use super::tensor::HostTensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

/// A compiled artifact: executable + its meta contract.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with validated inputs; returns the decomposed output
    /// tensors in meta order.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Borrowing variant of [`run`]: callers with persistent state
    /// (params/momenta held across steps) avoid cloning every tensor
    /// into the input vector each step (§Perf L3: one host copy per
    /// tensor per step instead of two).
    pub fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.validate(inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing `{}`", self.meta.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple.decompose_tuple().context("decomposing result tuple")?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "artifact `{}`: expected {} outputs, got {}",
                self.meta.name,
                self.meta.outputs.len(),
                parts.len()
            );
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }

    fn validate(&self, inputs: &[&HostTensor]) -> Result<()> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "artifact `{}`: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(self.meta.inputs.iter()).enumerate() {
            if t.shape() != spec.shape.as_slice() || t.dtype() != spec.dtype {
                bail!(
                    "artifact `{}` input[{i}] `{}`: expected {:?} {:?}, got {:?} {:?}",
                    self.meta.name,
                    spec.name,
                    spec.shape,
                    spec.dtype,
                    t.shape(),
                    t.dtype()
                );
            }
        }
        Ok(())
    }
}

/// The engine: one PJRT client + a per-process compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// Create a CPU engine rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            artifacts_dir: artifacts_dir.into(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Locate the artifacts dir: `$LUQ_ARTIFACTS`, `./artifacts`, or
    /// walking up from the executable (so examples work from any cwd).
    pub fn default_artifacts_dir() -> PathBuf {
        if let Ok(p) = std::env::var("LUQ_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = dir.join("artifacts");
            if cand.is_dir() {
                return cand;
            }
            if !dir.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let hlo = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let meta_path = self.artifacts_dir.join(format!("{name}.meta.json"));
        let meta = ArtifactMeta::load(&meta_path)?;
        let proto = xla::HloModuleProto::from_text_file(&hlo)
            .with_context(|| format!("parsing HLO text {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA-compiling `{name}`"))?;
        let e = Rc::new(Executable { meta, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// List available artifact names.
    pub fn available(&self) -> Result<Vec<String>> {
        let mut names = vec![];
        for entry in std::fs::read_dir(&self.artifacts_dir)
            .with_context(|| format!("reading {}", self.artifacts_dir.display()))?
        {
            let p = entry?.path();
            if let Some(n) = p.file_name().and_then(|n| n.to_str()) {
                if let Some(base) = n.strip_suffix(".hlo.txt") {
                    names.push(base.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }
}
