//! Shaped host tensors and their conversion to/from XLA literals.

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            _ => bail!("unsupported dtype `{s}`"),
        }
    }
}

/// A host-side tensor: row-major data + shape. The coordinator's working
/// currency; converted to literals at the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// The scalar value of a rank-0/1-element f32 tensor.
    pub fn item_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("item_f32 on tensor of {} elements", d.len());
        }
        Ok(d[0])
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        lit.reshape(&dims).context("reshape literal")
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 3]);
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn literal_roundtrip_i32_scalar() {
        let t = HostTensor::scalar_i32(42);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[] as &[usize]);
        assert_eq!(back.as_i32().unwrap(), &[42]);
    }

    #[test]
    fn shape_data_mismatch_panics() {
        let r = std::panic::catch_unwind(|| HostTensor::f32(vec![2, 2], vec![1.0]));
        assert!(r.is_err());
    }
}
