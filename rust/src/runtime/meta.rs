//! Artifact metadata sidecars (`artifacts/*.meta.json`), emitted by
//! `python/compile/aot.py` next to each HLO text file. The meta is the
//! contract between the layers: exact input/output order, shapes, dtypes,
//! model geometry, and the quantization scheme the graph was built with.

use crate::metrics::{parse_json, Json};
use crate::runtime::tensor::DType;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing name"))?
            .to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec `{name}` missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim in `{name}`")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = match j.get("dtype").and_then(Json::as_str) {
            Some(s) => DType::parse(s)?,
            None => DType::F32, // qgrads sidecar entries omit dtype
        };
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// Model geometry as recorded by the python side.
#[derive(Clone, Debug, Default)]
pub struct ModelMeta {
    pub kind: String,
    pub dim: usize,
    pub depth: usize,
    pub heads: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub input_dim: usize,
}

/// Quantization scheme the graph was compiled with.
#[derive(Clone, Debug, Default)]
pub struct SpecMeta {
    pub fwd: String,
    pub bwd: String,
    pub bwd_exp_bits: u32,
    pub smp: usize,
    pub use_kernels: bool,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub profile: String,
    pub stage: String,
    pub scheme: Option<String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Parameter layout (present for model artifacts).
    pub params: Vec<TensorSpec>,
    /// Neural-gradient shapes, one per quantized layer (train artifacts).
    pub qgrads: Vec<TensorSpec>,
    pub batch: usize,
    pub n_qlayers: usize,
    pub model: ModelMeta,
    pub spec: SpecMeta,
}

impl ArtifactMeta {
    pub fn load(path: impl AsRef<Path>) -> Result<ArtifactMeta> {
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let j = parse_json(&src).map_err(|e| anyhow!("parsing meta json: {e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            match j.get(key) {
                None => Ok(vec![]),
                Some(arr) => arr
                    .as_arr()
                    .ok_or_else(|| anyhow!("`{key}` not an array"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect(),
            }
        };
        let model = match j.get("model") {
            None => ModelMeta::default(),
            Some(m) => ModelMeta {
                kind: m.get("kind").and_then(Json::as_str).unwrap_or("").into(),
                dim: m.get("dim").and_then(Json::as_usize).unwrap_or(0),
                depth: m.get("depth").and_then(Json::as_usize).unwrap_or(0),
                heads: m.get("heads").and_then(Json::as_usize).unwrap_or(0),
                seq_len: m.get("seq_len").and_then(Json::as_usize).unwrap_or(0),
                vocab: m.get("vocab").and_then(Json::as_usize).unwrap_or(0),
                input_dim: m.get("input_dim").and_then(Json::as_usize).unwrap_or(0),
            },
        };
        let spec = match j.get("spec") {
            None => SpecMeta::default(),
            Some(s) => SpecMeta {
                fwd: s.get("fwd").and_then(Json::as_str).unwrap_or("").into(),
                bwd: s.get("bwd").and_then(Json::as_str).unwrap_or("").into(),
                bwd_exp_bits: s.get("bwd_exp_bits").and_then(Json::as_usize).unwrap_or(3) as u32,
                smp: s.get("smp").and_then(Json::as_usize).unwrap_or(1),
                use_kernels: matches!(s.get("use_kernels"), Some(Json::Bool(true))),
            },
        };
        let meta = ArtifactMeta {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("meta missing name"))?
                .to_string(),
            profile: j.get("profile").and_then(Json::as_str).unwrap_or("").into(),
            stage: j.get("stage").and_then(Json::as_str).unwrap_or("").into(),
            scheme: j.get("scheme").and_then(Json::as_str).map(String::from),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            params: specs("params")?,
            qgrads: specs("qgrads")?,
            batch: j.get("batch").and_then(Json::as_usize).unwrap_or(0),
            n_qlayers: j.get("n_qlayers").and_then(Json::as_usize).unwrap_or(0),
            model,
            spec,
        };
        if meta.inputs.is_empty() {
            bail!("artifact `{}` has no inputs", meta.name);
        }
        Ok(meta)
    }

    /// Total parameter count (for logging).
    pub fn param_count(&self) -> usize {
        self.params.iter().map(TensorSpec::numel).sum()
    }

    /// Index of the named input.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("artifact `{}` has no input `{name}`", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "name": "mlp_s__train__luq", "profile": "mlp_s", "stage": "train",
        "scheme": "luq",
        "model": {"kind": "mlp", "dim": 128, "depth": 3, "heads": 4,
                  "seq_len": 64, "vocab": 10, "input_dim": 768},
        "spec": {"fwd": "int4", "bwd": "luq", "bwd_exp_bits": 3, "smp": 1,
                 "use_kernels": false},
        "params": [{"name": "w_in", "shape": [768, 128], "dtype": "float32"}],
        "batch": 32, "n_qlayers": 2,
        "qgrads": [{"name": "g0", "shape": [32, 128]},
                   {"name": "g1", "shape": [32, 128]}],
        "inputs": [{"name": "w_in", "shape": [768, 128], "dtype": "float32"},
                   {"name": "y", "shape": [32], "dtype": "int32"}],
        "outputs": [{"name": "loss", "shape": [], "dtype": "float32"}]
    }"#;

    #[test]
    fn parses_sample_meta() {
        let j = parse_json(SAMPLE).unwrap();
        let m = ArtifactMeta::from_json(&j).unwrap();
        assert_eq!(m.name, "mlp_s__train__luq");
        assert_eq!(m.model.kind, "mlp");
        assert_eq!(m.spec.bwd, "luq");
        assert_eq!(m.inputs[1].dtype, DType::I32);
        assert_eq!(m.qgrads.len(), 2);
        assert_eq!(m.param_count(), 768 * 128);
        assert_eq!(m.input_index("y").unwrap(), 1);
        assert!(m.input_index("nope").is_err());
    }
}
