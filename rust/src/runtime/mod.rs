//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `.meta.json`) produced by `python/compile/aot.py` and executes them on
//! the CPU PJRT client. Python never runs here — this is the request
//! path.
//!
//! * [`tensor`] — [`HostTensor`]: shaped f32/i32 host buffers ↔ XLA
//!   literals.
//! * [`meta`] — the artifact manifest sidecar (input/output specs, model
//!   geometry, quantization scheme).
//! * [`engine`] — the PJRT client wrapper with a compile cache; one
//!   compiled executable per artifact, reused across every step.

pub mod engine;
pub mod meta;
pub mod tensor;

pub use engine::{Engine, Executable};
pub use meta::{ArtifactMeta, TensorSpec};
pub use tensor::{DType, HostTensor};
