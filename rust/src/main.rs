//! `luq` — the L3 coordinator CLI.
//!
//! ```text
//! luq list                          list available artifacts
//! luq inspect <artifact>            dump an artifact's IO contract
//! luq train --config <file.toml>    train per a run config
//! luq train --profile cnn_s --scheme luq [--steps N] [--seed S] ...
//! luq exp <id> [--steps N] [--seed S] [--out DIR]
//!     ids: table1 table2 table3 table4 table56 fig1bc fig2 fig3-left
//!          fig3-right fig4 fig5 fig6 a3 all
//! luq hw                            MF-BPROP exhaustive check + gate model
//! luq golden [--out FILE]           emit cross-layer golden vectors
//! luq serve --spec <job.toml> [--jobs N] [--workers W] [--queue D]
//!     multi-tenant job server: submit N copies of the spec (job ids
//!     offset per copy), stream per-step metrics as JSONL
//! ```
//!
//! Hand-rolled argument parsing: the offline registry has no clap.

use anyhow::{anyhow, bail, Context, Result};
use luq::config::RunConfig;
use luq::coordinator::experiments::{self, ExpOptions};
use luq::coordinator::TrainerOptions;
use luq::runtime::Engine;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` pairs after the positionals.
struct Flags<'a>(&'a [String]);

impl<'a> Flags<'a> {
    fn get(&self, key: &str) -> Option<&str> {
        self.0
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.0.get(i + 1))
            .map(String::as_str)
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("invalid value `{v}` for {key}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.0.iter().any(|a| a == key)
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = Flags(args);
    match cmd {
        "list" => {
            let engine = Engine::cpu(Engine::default_artifacts_dir())?;
            for name in engine.available()? {
                println!("{name}");
            }
            Ok(())
        }
        "inspect" => {
            let name = args.get(1).context("usage: luq inspect <artifact>")?;
            let dir = Engine::default_artifacts_dir();
            let meta = luq::runtime::ArtifactMeta::load(dir.join(format!("{name}.meta.json")))?;
            println!("artifact : {}", meta.name);
            println!(
                "stage    : {} (profile {}, scheme {:?})",
                meta.stage, meta.profile, meta.scheme
            );
            if !meta.model.kind.is_empty() {
                println!(
                    "model    : {} dim={} depth={} params={}",
                    meta.model.kind,
                    meta.model.dim,
                    meta.model.depth,
                    meta.param_count()
                );
                println!(
                    "quant    : fwd={} bwd={} eb={} smp={} kernels={}",
                    meta.spec.fwd,
                    meta.spec.bwd,
                    meta.spec.bwd_exp_bits,
                    meta.spec.smp,
                    meta.spec.use_kernels
                );
            }
            println!("inputs   :");
            for s in &meta.inputs {
                println!("  {:<12} {:?} {:?}", s.name, s.shape, s.dtype);
            }
            println!("outputs  :");
            for s in &meta.outputs {
                println!("  {:<12} {:?} {:?}", s.name, s.shape, s.dtype);
            }
            Ok(())
        }
        "train" => cmd_train(&flags),
        "exp" => cmd_exp(args, &flags),
        "hw" => cmd_hw(),
        "golden" => cmd_golden(&flags),
        "serve" => cmd_serve(&flags),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `luq help`)"),
    }
}

const HELP: &str = "luq — 4-bit training (LUQ, ICLR 2023) coordinator
commands: list | inspect <artifact> | train | exp <id> | hw | golden | serve
see `rust/src/main.rs` docs for flags";

/// `luq serve`: start the multi-tenant job server, submit `--jobs`
/// copies of the `--spec` TOML (job ids offset per copy so each draws
/// its own noise streams), and stream every job's metrics as JSONL.
fn cmd_serve(flags: &Flags) -> Result<()> {
    use luq::coordinator::{JobEvent, JobSpec, Server, ServerOptions};
    let spec_path = flags
        .get("--spec")
        .context("usage: luq serve --spec <job.toml> [--jobs N] [--workers W] [--queue D]")?;
    let src = std::fs::read_to_string(spec_path)
        .with_context(|| format!("reading {spec_path}"))?;
    let base = JobSpec::from_toml(&src).map_err(|e| anyhow!("job spec: {e}"))?;
    let jobs = flags.get_parse("--jobs", 1u64)?;
    let server = Server::start(ServerOptions {
        workers: flags.get_parse("--workers", 2usize)?,
        queue_depth: flags.get_parse("--queue", 8usize)?,
        inner_threads: flags.get_parse("--inner-threads", 1usize)?,
    });
    let mut handles = Vec::new();
    for k in 0..jobs {
        let mut spec = base.clone();
        spec.job_id = base.job_id + k;
        let id = spec.job_id;
        match server.submit(spec) {
            Ok(h) => handles.push(h),
            Err(e) => eprintln!("job {id}: rejected: {e}"),
        }
    }
    let mut failed = 0usize;
    for h in handles {
        let job_id = h.job_id();
        match h.wait() {
            Ok((events, summary)) => {
                for e in &events {
                    match e {
                        JobEvent::Step { step, loss, grad_norm } => println!(
                            "{{\"job\":{job_id},\"step\":{step},\"loss\":{loss},\
                             \"grad_norm\":{grad_norm}}}"
                        ),
                        JobEvent::Checkpoint { step, bytes } => println!(
                            "{{\"job\":{job_id},\"checkpoint_step\":{step},\
                             \"checkpoint_bytes\":{}}}",
                            bytes.len()
                        ),
                        _ => {}
                    }
                }
                println!(
                    "job {job_id}: done ({} steps, final loss {:.6}, ckpt crc32 {:#010x})",
                    summary.steps_run,
                    summary.final_loss(),
                    summary.checkpoint_crc32
                );
            }
            Err(e) => {
                failed += 1;
                eprintln!("job {job_id}: failed: {e}");
            }
        }
    }
    server.shutdown();
    if failed > 0 {
        bail!("{failed} job(s) failed");
    }
    Ok(())
}

fn cmd_train(flags: &Flags) -> Result<()> {
    let engine = Engine::cpu(Engine::default_artifacts_dir())?;
    let (profile, scheme, steps, seed, hindsight, noise_reuse, out, step_profile);
    if let Some(cfg_path) = flags.get("--config") {
        let src = std::fs::read_to_string(cfg_path)
            .with_context(|| format!("reading {cfg_path}"))?;
        let cfg = RunConfig::from_toml(&src).map_err(|e| anyhow!("config: {e}"))?;
        profile = match cfg.model.kind {
            luq::config::ModelKind::Mlp => "mlp_s".to_string(),
            luq::config::ModelKind::Cnn => "cnn_s".to_string(),
            luq::config::ModelKind::Transformer => "tfm_s".to_string(),
        };
        scheme = cfg.quant.bwd.name().to_string();
        steps = cfg.train.steps;
        seed = cfg.train.seed;
        hindsight = cfg.quant.hindsight;
        noise_reuse = cfg.quant.noise_reuse;
        out = cfg.out_dir;
        step_profile = cfg.profile;
    } else {
        profile = flags.get("--profile").unwrap_or("cnn_s").to_string();
        scheme = flags.get("--scheme").unwrap_or("luq").to_string();
        steps = flags.get_parse("--steps", 200usize)?;
        seed = flags.get_parse("--seed", 1u64)?;
        hindsight = flags.has("--hindsight");
        noise_reuse = flags.get_parse("--noise-reuse", 1usize)?;
        out = flags.get("--out").unwrap_or("runs").to_string();
        step_profile = luq::coordinator::StepProfile::paper_default();
    }
    let opts = ExpOptions {
        steps,
        seed,
        out_dir: out,
        log_every: flags.get_parse("--log-every", 20usize)?,
        eval_batches: 8,
    };
    std::fs::create_dir_all(&opts.out_dir)?;
    let r = experiments::run_scheme(
        &engine,
        &profile,
        &scheme,
        steps,
        &opts,
        TrainerOptions {
            seed,
            hindsight,
            noise_reuse,
            noise_engine: step_profile.noise_engine(),
            shards: step_profile.shards(),
            ..Default::default()
        },
    )?;
    println!(
        "final: eval_loss {:.4}  eval_acc {:.2}%  ({} steps)",
        r.eval_loss,
        r.eval_acc * 100.0,
        r.history.len()
    );
    Ok(())
}

fn cmd_exp(args: &[String], flags: &Flags) -> Result<()> {
    let id = args.get(1).context("usage: luq exp <id>")?.as_str();
    let opts = ExpOptions {
        steps: flags.get_parse("--steps", 200usize)?,
        seed: flags.get_parse("--seed", 1u64)?,
        out_dir: flags.get("--out").unwrap_or("runs").to_string(),
        log_every: flags.get_parse("--log-every", 0usize)?,
        eval_batches: flags.get_parse("--eval-batches", 8usize)?,
    };
    std::fs::create_dir_all(&opts.out_dir)?;
    // Hardware/analytic experiments need no engine.
    match id {
        "fig2" => {
            experiments::fig2(&opts)?;
            return Ok(());
        }
        "table56" => {
            experiments::table56(&opts)?;
            return Ok(());
        }
        "a3" => {
            experiments::a3(&opts)?;
            return Ok(());
        }
        _ => {}
    }
    let engine = Engine::cpu(Engine::default_artifacts_dir())?;
    match id {
        "table1" => experiments::table1(&engine, &opts)?,
        "table2" => experiments::table2(&engine, &opts)?,
        "table3" => experiments::table3(&engine, &opts)?,
        "table4" => experiments::table4(&engine, &opts)?,
        "fig1bc" => experiments::fig1bc(&engine, &opts)?,
        "fig3-left" => experiments::fig3_left(&engine, &opts)?,
        "fig3-right" => experiments::fig3_right(&engine, &opts)?,
        "fig4" => experiments::fig4(&engine, &opts)?,
        "fig5" => experiments::fig5(&engine, &opts)?,
        "fig6" => experiments::fig6(&engine, &opts)?,
        "all" => experiments::all(&engine, &opts)?,
        other => bail!("unknown experiment `{other}`"),
    };
    Ok(())
}

fn cmd_hw() -> Result<()> {
    use luq::hw::{mfbprop_multiply, reference_product, Fp4Code, Int4Code};
    let mut checked = 0;
    for a in Int4Code::all() {
        for g in Fp4Code::all() {
            let got = luq::hw::mfbprop::decode_fp7(mfbprop_multiply(a, g));
            let want = reference_product(a, g);
            assert_eq!(got, want, "mismatch at {a:?} x {g:?}");
            checked += 1;
        }
    }
    println!("MF-BPROP: {checked}/256 code pairs bit-exact vs reference multiply");
    let s = luq::hw::gates::area_summary();
    println!(
        "gates: standard {} vs MF-BPROP {} ({:.2}x); total saving {:.1}% (fp32 accum) / {:.1}% (fp16 accum)",
        s.standard_gemm,
        s.mfbprop,
        s.gemm_reduction,
        s.total_saving_fp32_accum * 100.0,
        s.total_saving_fp16_accum * 100.0
    );
    Ok(())
}

/// Emit golden vectors: fixed inputs + noise + the rust quantizers'
/// outputs, as JSON consumed by `python/tests/test_cross_layer.py`.
/// This pins the rust substrate and the jax graphs to identical
/// semantics.
fn cmd_golden(flags: &Flags) -> Result<()> {
    use ::luq::metrics::Json;
    use ::luq::quant::{
        LogFormat, LogQuantConfig, LogQuantizer, Radix4Format, Radix4Quantizer,
        UniformQuantizer, UniformRounding,
    };
    use ::luq::rng::Xoshiro256;

    let out = flags
        .get("--out")
        .unwrap_or("python/tests/golden/quantizers.json");
    let mut rng = Xoshiro256::seed_from_u64(0x601d);
    let n = 257;
    let x: Vec<f32> = (0..n).map(|_| rng.signed_lognormal_f32(0.0, 2.0)).collect();
    let noise: Vec<f32> = (0..n).map(|_| rng.uniform_f32()).collect();
    let max_abs = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));

    let arr = |v: &[f32]| Json::Arr(v.iter().map(|&f| Json::num(f as f64)).collect());
    let mut cases = vec![
        ("x".to_string(), arr(&x)),
        ("noise".to_string(), arr(&noise)),
        ("max_abs".to_string(), Json::num(max_abs as f64)),
    ];

    for (name, cfg) in [
        ("luq", LogQuantConfig::luq(LogFormat::FP4)),
        ("naive", LogQuantConfig::naive(LogFormat::FP4)),
        ("naive_sp", LogQuantConfig::naive_sp(LogFormat::FP4)),
        ("naive_rdnp", LogQuantConfig::naive_rdnp(LogFormat::FP4)),
        ("sp_rdnp", LogQuantConfig::sp_rdnp(LogFormat::FP4)),
    ] {
        let q = LogQuantizer::new(cfg);
        let mut y = vec![0.0f32; n];
        q.quantize_into(&x, &noise, &mut y);
        cases.push((name.to_string(), arr(&y)));
    }
    // radix-4 TPR
    let r4 = Radix4Quantizer::new(Radix4Format::FP4);
    let (dw, dx) = r4.quantize_tpr(&x);
    cases.push(("ultralow_dw".into(), arr(&dw)));
    cases.push(("ultralow_dx".into(), arr(&dx)));
    // uniform int4 SR / RDN with clip = max
    let sr = UniformQuantizer::new(4, max_abs, UniformRounding::Stochastic);
    let mut y = vec![0.0f32; n];
    sr.quantize_into(&x, &noise, &mut y);
    cases.push(("int_sr".into(), arr(&y)));
    let rdn = UniformQuantizer::new(4, max_abs, UniformRounding::Rdn);
    rdn.quantize_into(&x, &[], &mut y);
    cases.push(("int_rdn".into(), arr(&y)));
    // SAWB coefficients for the pinned-constant check
    let (c1, c2) = luq::quant::sawb::default_coefficients(4);
    cases.push(("sawb_c1".into(), Json::num(c1 as f64)));
    cases.push(("sawb_c2".into(), Json::num(c2 as f64)));

    let j = Json::Obj(cases);
    if let Some(parent) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(out, j.render())?;
    println!("wrote {out}");
    Ok(())
}
