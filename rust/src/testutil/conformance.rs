//! Cross-format GEMM conformance harness — the engine's bit-exactness
//! contract as a systematically enforced property instead of per-kernel
//! ad-hoc tests.
//!
//! Every LUT instantiation of [`crate::hw::qgemm`] — backward INT4×FP4
//! (MF-BPROP), forward signed INT4×INT4, and radix-4 TPR — promises that
//! every kernel variant (scalar decode loop, flat LUT, tiled LUT, and the
//! multithreaded row-band driver at any thread count) is **bit-identical**
//! to the format's decode-then-f32-matmul oracle. This module drives all
//! three formats — plus a corrupted-operand row and a forward-format
//! layer-step row (thread-count invariance of the full
//! [`QuantizedLayerStep`] and LUT↔decode agreement) — through one table:
//! seeded randomized shapes plus a fixed
//! edge-shape list (`m`/`n` ∈ {0, 1}, `k` ∈ {0, 1, odd}, tile boundaries)
//! × thread counts {1, 2, num_cpus}, with every packed operand emitted by
//! the format's real matrix emitter — once densely and once at a row
//! stride **wider than the packed row**, asserting the two emissions
//! agree byte-for-byte before the GEMM runs.
//!
//! The integer-format rows additionally sweep every [`KernelPath`] the
//! host can run ([`conformance_kernel_paths`]) through the explicit-path
//! entry points at each thread count, so the SIMD nibble-split kernels
//! join the bit-exactness contract rather than weakening it: a shuffle
//! kernel that diverges from the decode oracle by one ULP on one element
//! fails here, clean and corrupted operands alike.
//!
//! The `sharded-reduction` row enforces the opt-in **tier-2 contract**
//! of the K-sharded engine: for every [`conformance_shard_configs`]
//! entry (including `n_shards` ∈ {1, `k`, > `k`}) × kernel path ×
//! thread count, clean and corrupted operands alike, the output must be
//! bit-identical to an independently built per-block decode-oracle
//! pairwise reduction tree — and the 1-shard config must reproduce the
//! classic unsharded oracle exactly, which keeps tier 1 nested inside
//! tier 2 rather than forked from it.
//!
//! The `step-profile` row enforces the session-API contract on top: for
//! every [`conformance_step_profiles`] entry — one per [`StepProfile`]
//! constructor (paper defaults, builder, TOML) — the profile-built
//! [`QuantizedLayerStep`] must reproduce the hand-wired legacy
//! construction bit-for-bit at every thread count, so the unified config
//! surface can never drift from the kernels it configures.
//!
//! [`run_conformance`] panics with the format, case, and shape on the
//! first divergence (the `prop_check` reporting convention), so a
//! replaying `cargo test conformance` pinpoints the exact case.

use crate::config::toml::parse_toml;
use crate::coordinator::layer_step::{ForwardFormat, QuantizedLayerStep};
use crate::coordinator::profile::StepProfile;
use crate::hw::mfbprop::{Fp4Code, Int4Code};
use crate::hw::qgemm::{
    int4_product_lut, product_lut, qgemm_decode_oracle, qgemm_int4_decode_oracle,
    qgemm_int4_flat, qgemm_int4_into, qgemm_int4_mt_with, qgemm_int4_mt_with_path,
    qgemm_int4_scalar_reference, qgemm_int4_sharded_mt_with, qgemm_int4_sharded_mt_with_path,
    qgemm_int4_with, qgemm_packed_flat, qgemm_packed_into, qgemm_packed_mt_with,
    qgemm_packed_sharded_mt_with, qgemm_packed_with, qgemm_radix4_decode_oracle,
    qgemm_radix4_flat, qgemm_radix4_into, qgemm_radix4_mt_with, qgemm_radix4_mt_with_path,
    qgemm_radix4_scalar_reference, qgemm_radix4_sharded_mt_with,
    qgemm_radix4_sharded_mt_with_path, qgemm_radix4_with, qgemm_scalar_reference,
    radix4_product_lut, KernelPath, QgemmScratch, ShardConfig, TILE_M, TILE_N,
};
use crate::quant::radix4::{radix4_unit_value, Radix4Format, Radix4Quantizer, TprPhase};
use crate::quant::{
    LogFormat, LogQuantConfig, LogQuantizer, UniformQuantizer, UniformRounding,
};
use crate::rng::Xoshiro256;
use crate::testutil::fault::FaultPlan;

/// One LUT format's hookup into the harness: a name for failure reports
/// and a checker that builds operands for a `(m, k, n)` shape (drawing
/// from the shared seeded generator) and verifies every kernel variant
/// against the format's decode oracle at each thread count.
pub struct FormatConformance {
    pub name: &'static str,
    pub check: fn(&mut Xoshiro256, usize, usize, usize, &[usize]) -> Result<(), String>,
}

/// The format table: every LUT instantiation of the generic engine. A new
/// format joins the enforced contract by adding one row here.
pub fn conformance_formats() -> Vec<FormatConformance> {
    vec![
        FormatConformance { name: "backward-int4xfp4", check: check_backward },
        FormatConformance { name: "forward-int4xint4", check: check_forward },
        FormatConformance { name: "radix4-tpr", check: check_radix4 },
        FormatConformance { name: "corrupted-operand", check: check_corrupted },
        FormatConformance { name: "forward-format-layer-step", check: check_layer_step },
        FormatConformance { name: "sharded-reduction", check: check_sharded },
        FormatConformance { name: "step-profile", check: check_profile },
    ]
}

/// Session profiles the `step-profile` row sweeps — one entry per
/// [`StepProfile`] constructor ([`StepProfile::paper_default`], the
/// builder's [`StepProfileBuilder::build`], and
/// [`StepProfile::from_toml_section`]), listed explicitly so every way
/// to build a session config is visibly wired into the harness for the
/// tidy coverage rule. The TOML entry parses a non-default section so
/// the deserializer path is exercised with real knob values, not just
/// defaults.
///
/// [`StepProfileBuilder::build`]: crate::coordinator::profile::StepProfileBuilder::build
pub fn conformance_step_profiles() -> Vec<StepProfile> {
    let toml_src = "[profile]\nformat = \"radix4_tpr\"\nbits = 4\nshards = 2\n\
                    kernel_path = \"portable\"\nnoise_engine = \"xoshiro\"\n";
    let section = parse_toml(toml_src)
        .expect("step-profile TOML parses")
        .remove("profile")
        .expect("[profile] section present");
    vec![
        StepProfile::paper_default(),
        StepProfile::builder()
            .format(ForwardFormat::Radix4Tpr)
            .shards(ShardConfig::with_shards(3))
            .build()
            .expect("builder profile is valid"),
        StepProfile::from_toml_section(&section).expect("TOML profile is valid"),
    ]
}

/// Shard configurations the sharded-reduction row sweeps — the opt-in
/// **tier-2 contract**: output is a pure function of `(operands, shape,
/// ShardConfig)`, never of thread count. Listed explicitly so all three
/// [`ShardConfig`] constructors are visibly wired into the harness for
/// the tidy coverage rule; the degenerate entries (`k` shards, `> k`
/// shards) pin the empty-trailing-shard behaviour, and
/// [`ShardConfig::from_env`] folds the CI `QGEMM_SHARDS` matrix leg into
/// the sweep (it duplicates an explicit entry on unset hosts, which is
/// fine — the row is idempotent per config).
pub fn conformance_shard_configs(k: usize) -> Vec<ShardConfig> {
    vec![
        ShardConfig::single(),
        ShardConfig::with_shards(2),
        ShardConfig::with_shards(3),
        ShardConfig::with_shards(4),
        ShardConfig::with_shards(k.max(1)),
        ShardConfig::with_shards(k + 3),
        ShardConfig::from_env(),
    ]
}

/// Thread counts the multithreaded driver is checked at: single-threaded,
/// the smallest parallel split, and the host's full parallelism.
pub fn conformance_thread_counts() -> Vec<usize> {
    let hw = std::thread::available_parallelism().map_or(2, |p| p.get());
    let mut t = vec![1usize, 2, hw];
    t.sort_unstable();
    t.dedup();
    t
}

/// Kernel paths the integer-format rows sweep: every dispatchable
/// implementation the host can run — [`KernelPath::Scalar`] and
/// [`KernelPath::Portable`] always, plus [`KernelPath::Avx2`] where the
/// feature is detected. Listed explicitly (not via
/// [`KernelPath::available`]) so each variant is visibly wired into the
/// harness for the tidy coverage rule.
pub fn conformance_kernel_paths() -> Vec<KernelPath> {
    [KernelPath::Scalar, KernelPath::Portable, KernelPath::Avx2]
        .into_iter()
        .filter(|p| p.is_available())
        .collect()
}

/// Deliberate edge shapes: empty operands in each dimension, single
/// rows/columns, `k` = 1 (one half byte per row), odd `k` (half-filled
/// trailing bytes), and exact/off-by-one tile boundaries.
pub fn conformance_edge_shapes() -> Vec<(usize, usize, usize)> {
    vec![
        (0, 5, 3),
        (4, 5, 0),
        (2, 0, 3),
        (1, 1, 1),
        (1, 7, 1),
        (3, 1, 5),
        (TILE_M, 16, TILE_N),
        (TILE_M + 1, 33, TILE_N - 1),
    ]
}

/// Run the full conformance table: every format × (edge shapes +
/// `random_cases` seeded random shapes) × every thread count. Panics with
/// format, case, and shape on the first divergence.
pub fn run_conformance(seed: u64, random_cases: usize) {
    let threads = conformance_thread_counts();
    for fmt in conformance_formats() {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        for (i, &(m, k, n)) in conformance_edge_shapes().iter().enumerate() {
            if let Err(msg) = (fmt.check)(&mut rng, m, k, n, &threads) {
                panic!(
                    "conformance[{}] edge case {i} (m={m} k={k} n={n}, threads {threads:?}): {msg}",
                    fmt.name
                );
            }
        }
        for c in 0..random_cases {
            let m = rng.uniform_usize(2 * TILE_M + 4);
            let k = rng.uniform_usize(67);
            let n = rng.uniform_usize(2 * TILE_N + 4);
            if let Err(msg) = (fmt.check)(&mut rng, m, k, n, &threads) {
                panic!(
                    "conformance[{}] random case {c}/{random_cases} (seed {seed}, m={m} k={k} \
                     n={n}, threads {threads:?}): {msg}",
                    fmt.name
                );
            }
        }
    }
}

fn bits_check(what: &str, got: &[f32], want: &[f32]) -> Result<(), String> {
    if got.len() < want.len() {
        return Err(format!("{what}: output too short ({} < {})", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        if g.to_bits() != w.to_bits() {
            return Err(format!(
                "{what}[{i}]: {g} ({:#010x}) vs {w} ({:#010x})",
                g.to_bits(),
                w.to_bits()
            ));
        }
    }
    Ok(())
}

fn random_codes(rng: &mut Xoshiro256, len: usize) -> Vec<Int4Code> {
    (0..len).map(|_| Int4Code::from_nibble((rng.next_u64() & 0xF) as u8)).collect()
}

/// Emit `rows × cols` packed codes twice through `emit` — densely and at
/// a row stride 3 bytes wider than the packed row — and require the two
/// emissions to agree byte-for-byte. Returns the dense operand the GEMM
/// consumes.
fn emit_dense_and_strided(
    rows: usize,
    cols: usize,
    mut emit: impl FnMut(&mut [u8], usize),
) -> Result<Vec<u8>, String> {
    let rb = cols.div_ceil(2);
    let mut dense = vec![0u8; rows * rb];
    emit(&mut dense, rb);
    let stride = rb + 3;
    let strided_len = if rows == 0 { 0 } else { (rows - 1) * stride + rb };
    let mut strided = vec![0xEEu8; strided_len];
    emit(&mut strided, stride);
    for r in 0..rows {
        if strided[r * stride..r * stride + rb] != dense[r * rb..(r + 1) * rb] {
            return Err(format!(
                "strided emission (stride {stride} > {rb} row bytes) row {r} differs from dense"
            ));
        }
    }
    Ok(dense)
}

/// Backward INT4×FP4: A as random typed INT4 codes, B emitted by the LUQ
/// matrix code emitter (dense and strided) from lognormal gradients.
fn check_backward(
    rng: &mut Xoshiro256,
    m: usize,
    k: usize,
    n: usize,
    threads: &[usize],
) -> Result<(), String> {
    let a = random_codes(rng, m * k);
    let g: Vec<f32> = (0..n * k).map(|_| rng.signed_lognormal_f32(0.0, 2.0)).collect();
    let mut noise = vec![0.0f32; n * k];
    rng.fill_uniform(&mut noise);
    let q = LogQuantizer::new(LogQuantConfig::luq(LogFormat::FP4));
    let b = emit_dense_and_strided(n, k, |buf, stride| {
        q.quantize_to_codes_matrix_into(&g, n, k, &noise, buf, stride);
    })?;

    let want = qgemm_decode_oracle(&a, &b, m, k, n);
    let mut scratch = QgemmScratch::new();
    let mut out = vec![f32::NAN; m * n];
    qgemm_packed_with(&a, &b, m, k, n, &mut out, &mut scratch);
    bits_check("tiled", &out, &want)?;
    out.fill(f32::NAN);
    qgemm_packed_flat(&a, &b, m, k, n, &mut out);
    bits_check("flat", &out, &want)?;
    out.fill(f32::NAN);
    qgemm_scalar_reference(&a, &b, m, k, n, &mut out);
    bits_check("scalar", &out, &want)?;
    out.fill(f32::NAN);
    qgemm_packed_into(&a, &b, m, k, n, &mut out);
    bits_check("into", &out, &want)?;
    for &t in threads {
        out.fill(f32::NAN);
        qgemm_packed_mt_with(&a, &b, m, k, n, &mut out, t, &mut scratch);
        bits_check(&format!("mt[{t}]"), &out, &want)?;
    }
    Ok(())
}

/// Forward signed INT4×INT4: both operands emitted by the uniform fused
/// matrix emitter (dense and strided) — A stochastically rounded, B with
/// RDN, covering both emission modes.
fn check_forward(
    rng: &mut Xoshiro256,
    m: usize,
    k: usize,
    n: usize,
    threads: &[usize],
) -> Result<(), String> {
    let acts: Vec<f32> = (0..m * k).map(|_| rng.normal_ms_f32(0.0, 1.5)).collect();
    let wts: Vec<f32> = (0..n * k).map(|_| rng.normal_ms_f32(0.0, 0.5)).collect();
    let mut noise = vec![0.0f32; m * k];
    rng.fill_uniform(&mut noise);
    let aq = UniformQuantizer::new(4, 2.5, UniformRounding::Stochastic);
    let wq = UniformQuantizer::new(4, 1.5, UniformRounding::Rdn);
    let a = emit_dense_and_strided(m, k, |buf, stride| {
        aq.encode_packed_matrix_into(&acts, m, k, &noise, buf, stride);
    })?;
    let b = emit_dense_and_strided(n, k, |buf, stride| {
        wq.encode_packed_matrix_into(&wts, n, k, &[], buf, stride);
    })?;

    let want = qgemm_int4_decode_oracle(&a, &b, m, k, n);
    let mut scratch = QgemmScratch::new();
    let mut out = vec![f32::NAN; m * n];
    qgemm_int4_with(&a, &b, m, k, n, &mut out, &mut scratch);
    bits_check("tiled", &out, &want)?;
    out.fill(f32::NAN);
    qgemm_int4_flat(&a, &b, m, k, n, &mut out);
    bits_check("flat", &out, &want)?;
    out.fill(f32::NAN);
    qgemm_int4_scalar_reference(&a, &b, m, k, n, &mut out);
    bits_check("scalar", &out, &want)?;
    out.fill(f32::NAN);
    qgemm_int4_into(&a, &b, m, k, n, &mut out);
    bits_check("into", &out, &want)?;
    for &t in threads {
        out.fill(f32::NAN);
        qgemm_int4_mt_with(&a, &b, m, k, n, &mut out, t, &mut scratch);
        bits_check(&format!("mt[{t}]"), &out, &want)?;
    }
    for path in conformance_kernel_paths() {
        for &t in threads {
            out.fill(f32::NAN);
            qgemm_int4_mt_with_path(&a, &b, m, k, n, &mut out, t, &mut scratch, path);
            bits_check(&format!("{}[{t}]", path.label()), &out, &want)?;
        }
    }
    Ok(())
}

/// Radix-4 TPR: A as random typed INT4 codes, B emitted by the radix-4
/// fused matrix emitter (dense and strided) from lognormal gradients, in
/// **both** TPR phases — each phase is a full GEMM of its own.
fn check_radix4(
    rng: &mut Xoshiro256,
    m: usize,
    k: usize,
    n: usize,
    threads: &[usize],
) -> Result<(), String> {
    let a = random_codes(rng, m * k);
    let g: Vec<f32> = (0..n * k).map(|_| rng.signed_lognormal_f32(0.0, 3.0)).collect();
    let r4 = Radix4Quantizer::new(Radix4Format::FP4);
    for phase in [TprPhase::Base, TprPhase::Shifted] {
        let b = emit_dense_and_strided(n, k, |buf, stride| {
            r4.encode_packed_matrix_into(&g, n, k, phase, buf, stride);
        })?;

        let want = qgemm_radix4_decode_oracle(&a, &b, m, k, n);
        let mut scratch = QgemmScratch::new();
        let mut out = vec![f32::NAN; m * n];
        qgemm_radix4_with(&a, &b, m, k, n, &mut out, &mut scratch);
        bits_check(&format!("{phase:?}/tiled"), &out, &want)?;
        out.fill(f32::NAN);
        qgemm_radix4_flat(&a, &b, m, k, n, &mut out);
        bits_check(&format!("{phase:?}/flat"), &out, &want)?;
        out.fill(f32::NAN);
        qgemm_radix4_scalar_reference(&a, &b, m, k, n, &mut out);
        bits_check(&format!("{phase:?}/scalar"), &out, &want)?;
        out.fill(f32::NAN);
        qgemm_radix4_into(&a, &b, m, k, n, &mut out);
        bits_check(&format!("{phase:?}/into"), &out, &want)?;
        for &t in threads {
            out.fill(f32::NAN);
            qgemm_radix4_mt_with(&a, &b, m, k, n, &mut out, t, &mut scratch);
            bits_check(&format!("{phase:?}/mt[{t}]"), &out, &want)?;
        }
        for path in conformance_kernel_paths() {
            for &t in threads {
                out.fill(f32::NAN);
                qgemm_radix4_mt_with_path(&a, &b, m, k, n, &mut out, t, &mut scratch, path);
                bits_check(&format!("{phase:?}/{}[{t}]", path.label()), &out, &want)?;
            }
        }
    }
    Ok(())
}

/// Corrupted-operand row: flip bits in each format's packed B operand
/// (deterministically, via a [`FaultPlan`] keyed off the shared case
/// generator) and require two things of every kernel variant. First,
/// **conformance survives corruption**: the kernels must stay
/// bit-identical to the decode oracle *on the corrupted bytes* — garbage
/// in may be garbage out, but it must be the same garbage everywhere, at
/// every thread count. Second, **corruption is benign at the wire level**:
/// all 256 nibble byte values decode to finite products in every LUT, so
/// a flipped bit in a packed stream can bound-err a value but never mint
/// a NaN/Inf — the supervisor relies on this when it treats packed-stream
/// damage as silent-but-finite rather than a NonFinite fault.
fn check_corrupted(
    rng: &mut Xoshiro256,
    m: usize,
    k: usize,
    n: usize,
    threads: &[usize],
) -> Result<(), String> {
    let mut plan = FaultPlan::new(rng.next_u64());
    let rb = k.div_ceil(2);
    let finite_check = |what: &str, out: &[f32]| -> Result<(), String> {
        match out.iter().position(|v| !v.is_finite()) {
            Some(i) => Err(format!("{what}[{i}]: non-finite {} from corrupt operand", out[i])),
            None => Ok(()),
        }
    };

    // Backward INT4×FP4 on corrupted packed gradients.
    let a = random_codes(rng, m * k);
    let g: Vec<f32> = (0..n * k).map(|_| rng.signed_lognormal_f32(0.0, 2.0)).collect();
    let mut noise = vec![0.0f32; n * k];
    rng.fill_uniform(&mut noise);
    let q = LogQuantizer::new(LogQuantConfig::luq(LogFormat::FP4));
    let mut b = vec![0u8; n * rb];
    q.quantize_to_codes_matrix_into(&g, n, k, &noise, &mut b, rb);
    if !b.is_empty() {
        plan.flip_bits(&mut b, 1 + b.len() / 7);
    }
    let want = qgemm_decode_oracle(&a, &b, m, k, n);
    finite_check("backward/oracle", &want)?;
    let mut scratch = QgemmScratch::new();
    let mut out = vec![f32::NAN; m * n];
    qgemm_packed_with(&a, &b, m, k, n, &mut out, &mut scratch);
    bits_check("backward/tiled", &out, &want)?;
    out.fill(f32::NAN);
    qgemm_packed_flat(&a, &b, m, k, n, &mut out);
    bits_check("backward/flat", &out, &want)?;
    for &t in threads {
        out.fill(f32::NAN);
        qgemm_packed_mt_with(&a, &b, m, k, n, &mut out, t, &mut scratch);
        bits_check(&format!("backward/mt[{t}]"), &out, &want)?;
    }

    // Forward INT4×INT4 on a corrupted packed weight operand.
    let wts: Vec<f32> = (0..n * k).map(|_| rng.normal_ms_f32(0.0, 0.5)).collect();
    let wq = UniformQuantizer::new(4, 1.5, UniformRounding::Rdn);
    let mut bw = vec![0u8; n * rb];
    wq.encode_packed_matrix_into(&wts, n, k, &[], &mut bw, rb);
    if !bw.is_empty() {
        plan.flip_bits(&mut bw, 1 + bw.len() / 7);
    }
    let af: Vec<u8> = (0..m * rb).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
    let want = qgemm_int4_decode_oracle(&af, &bw, m, k, n);
    finite_check("forward/oracle", &want)?;
    out.fill(f32::NAN);
    qgemm_int4_with(&af, &bw, m, k, n, &mut out, &mut scratch);
    bits_check("forward/tiled", &out, &want)?;
    out.fill(f32::NAN);
    qgemm_int4_flat(&af, &bw, m, k, n, &mut out);
    bits_check("forward/flat", &out, &want)?;
    for &t in threads {
        out.fill(f32::NAN);
        qgemm_int4_mt_with(&af, &bw, m, k, n, &mut out, t, &mut scratch);
        bits_check(&format!("forward/mt[{t}]"), &out, &want)?;
    }
    for path in conformance_kernel_paths() {
        out.fill(f32::NAN);
        qgemm_int4_mt_with_path(&af, &bw, m, k, n, &mut out, 2, &mut scratch, path);
        bits_check(&format!("forward/{}", path.label()), &out, &want)?;
    }

    // Radix-4 TPR on a corrupted packed gradient operand (base phase —
    // the LUT is phase-independent).
    let r4 = Radix4Quantizer::new(Radix4Format::FP4);
    let mut br = vec![0u8; n * rb];
    r4.encode_packed_matrix_into(&g, n, k, TprPhase::Base, &mut br, rb);
    if !br.is_empty() {
        plan.flip_bits(&mut br, 1 + br.len() / 7);
    }
    let want = qgemm_radix4_decode_oracle(&a, &br, m, k, n);
    finite_check("radix4/oracle", &want)?;
    out.fill(f32::NAN);
    qgemm_radix4_with(&a, &br, m, k, n, &mut out, &mut scratch);
    bits_check("radix4/tiled", &out, &want)?;
    out.fill(f32::NAN);
    qgemm_radix4_flat(&a, &br, m, k, n, &mut out);
    bits_check("radix4/flat", &out, &want)?;
    for &t in threads {
        out.fill(f32::NAN);
        qgemm_radix4_mt_with(&a, &br, m, k, n, &mut out, t, &mut scratch);
        bits_check(&format!("radix4/mt[{t}]"), &out, &want)?;
    }
    for path in conformance_kernel_paths() {
        out.fill(f32::NAN);
        qgemm_radix4_mt_with_path(&a, &br, m, k, n, &mut out, 2, &mut scratch, path);
        bits_check(&format!("radix4/{}", path.label()), &out, &want)?;
    }
    Ok(())
}

/// Forward-format layer-step row: the full [`QuantizedLayerStep`] —
/// forward + dx + dW — must be bit-identical at every thread count to its
/// single-threaded run, in **both** [`ForwardFormat`]s; and the three
/// process-wide product LUTs the kernels index must agree bit-for-bit
/// with decode-then-multiply on all 256 nibble pairs (re-checked per case
/// so a corrupted `OnceLock` table cannot hide behind one passing case).
/// Degenerate dims are clamped to 1: a layer step consumes nonempty
/// tensors; the kernels' own empty-shape behaviour is the rows above.
fn check_layer_step(
    rng: &mut Xoshiro256,
    m: usize,
    k: usize,
    n: usize,
    threads: &[usize],
) -> Result<(), String> {
    for a in 0..16u8 {
        for b in 0..16u8 {
            let i4 = Int4Code::from_nibble(a).value();
            let fp4 = Fp4Code::from_nibble(b).value();
            let ib = Int4Code::from_nibble(b).value();
            let entries = [
                ("backward", product_lut().product(a, b), i4 * fp4),
                ("forward", int4_product_lut().product(a, b), i4 * ib),
                ("radix4", radix4_product_lut().product(a, b), i4 * radix4_unit_value(b)),
            ];
            for (name, got, want) in entries {
                if got.to_bits() != want.to_bits() {
                    return Err(format!(
                        "{name} lut[{a:#x}][{b:#x}] = {got} differs from decode product {want}"
                    ));
                }
            }
        }
    }

    let (batch, d_in, d_out) = (m.max(1), k.max(1), n.max(1));
    let acts: Vec<f32> = (0..batch * d_in).map(|_| rng.normal_ms_f32(0.0, 1.2)).collect();
    let wts: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_ms_f32(0.0, 0.4)).collect();
    let grads: Vec<f32> =
        (0..batch * d_out).map(|_| rng.signed_lognormal_f32(0.0, 2.0)).collect();
    let seed = rng.next_u64();
    for format in [ForwardFormat::Sawb, ForwardFormat::Radix4Tpr] {
        let mut reference: QuantizedLayerStep =
            QuantizedLayerStep::with_format(LogQuantConfig::luq(LogFormat::FP4), 4, format);
        let mut r = Xoshiro256::seed_from_u64(seed);
        reference.step(&acts, &wts, &grads, batch, d_in, d_out, &mut r, 1);
        for &t in threads {
            let mut step: QuantizedLayerStep =
                QuantizedLayerStep::with_format(LogQuantConfig::luq(LogFormat::FP4), 4, format);
            let mut r = Xoshiro256::seed_from_u64(seed);
            step.step(&acts, &wts, &grads, batch, d_in, d_out, &mut r, t);
            bits_check(&format!("{format:?}/y mt[{t}]"), step.y(), reference.y())?;
            bits_check(&format!("{format:?}/dx_t mt[{t}]"), step.dx_t(), reference.dx_t())?;
            bits_check(&format!("{format:?}/dw_t mt[{t}]"), step.dw_t(), reference.dw_t())?;
        }
    }
    Ok(())
}

/// Session-profile row: every [`conformance_step_profiles`] entry — one
/// per [`StepProfile`] constructor — must drive
/// [`StepProfile::layer_step`] to the exact bits of the hand-wired
/// legacy construction (`with_format` + `set_shards` +
/// `set_kernel_path`), at every thread count. This is the harness-level
/// version of the trainer's API-redesign regression test: the unified
/// session surface configures the kernels, it never reroutes them.
/// Degenerate dims are clamped to 1 as in the layer-step row.
fn check_profile(
    rng: &mut Xoshiro256,
    m: usize,
    k: usize,
    n: usize,
    threads: &[usize],
) -> Result<(), String> {
    let (batch, d_in, d_out) = (m.max(1), k.max(1), n.max(1));
    let acts: Vec<f32> = (0..batch * d_in).map(|_| rng.normal_ms_f32(0.0, 1.2)).collect();
    let wts: Vec<f32> = (0..d_out * d_in).map(|_| rng.normal_ms_f32(0.0, 0.4)).collect();
    let grads: Vec<f32> =
        (0..batch * d_out).map(|_| rng.signed_lognormal_f32(0.0, 2.0)).collect();
    let seed = rng.next_u64();
    let grad_cfg = LogQuantConfig::luq(LogFormat::FP4);
    for profile in conformance_step_profiles() {
        let mut legacy: QuantizedLayerStep =
            QuantizedLayerStep::with_format(grad_cfg, profile.bits(), profile.format());
        legacy.set_shards(profile.shards());
        legacy.set_kernel_path(profile.kernel_path());
        let mut r = Xoshiro256::seed_from_u64(seed);
        legacy.step(&acts, &wts, &grads, batch, d_in, d_out, &mut r, 1);
        for &t in threads {
            let mut step: QuantizedLayerStep = profile.layer_step(grad_cfg);
            let mut r = Xoshiro256::seed_from_u64(seed);
            step.step(&acts, &wts, &grads, batch, d_in, d_out, &mut r, t);
            let tag = format!("{:?}/{}sh mt[{t}]", profile.format(), profile.shards().n_shards());
            bits_check(&format!("{tag}/y"), step.y(), legacy.y())?;
            bits_check(&format!("{tag}/dx_t"), step.dx_t(), legacy.dx_t())?;
            bits_check(&format!("{tag}/dw_t"), step.dw_t(), legacy.dw_t())?;
        }
    }
    Ok(())
}

/// Fold per-shard partial products with the fixed pairwise tree the
/// engine promises: adjacent pairs combine (`left += right`), an odd
/// leftover rides to the next level. Built here from scratch — the
/// reference must not share the engine's reduction code.
fn pairwise_tree(mut bufs: Vec<Vec<f32>>) -> Vec<f32> {
    while bufs.len() > 1 {
        let mut next = Vec::new();
        let mut it = bufs.into_iter();
        while let Some(left) = it.next() {
            match it.next() {
                Some(right) => {
                    next.push(left.iter().zip(&right).map(|(l, r)| l + r).collect())
                }
                None => next.push(left),
            }
        }
        bufs = next;
    }
    bufs.pop().unwrap_or_default()
}

/// Copy the byte span `[b0, b0 + bd)` of every packed row into a dense
/// block operand (`rb` is the source row stride in bytes).
fn packed_block(src: &[u8], rows: usize, rb: usize, b0: usize, bd: usize) -> Vec<u8> {
    let mut out = vec![0u8; rows * bd];
    for r in 0..rows {
        out[r * bd..(r + 1) * bd].copy_from_slice(&src[r * rb + b0..r * rb + b0 + bd]);
    }
    out
}

/// Copy the element span `[k0, k1)` of every typed-code row.
fn codes_block(src: &[Int4Code], rows: usize, k: usize, k0: usize, k1: usize) -> Vec<Int4Code> {
    let mut out = Vec::with_capacity(rows * (k1 - k0));
    for r in 0..rows {
        out.extend_from_slice(&src[r * k + k0..r * k + k1]);
    }
    out
}

/// The tier-2 reference: run the format's **decode oracle on each shard
/// block independently** (shard spans are byte-aligned, so block
/// operands are whole-byte row slices) and fold the partials with
/// [`pairwise_tree`]. For the 1-shard config this degenerates to the
/// plain unsharded decode oracle — the tier-1 bitwise row.
fn sharded_oracle(
    shards: ShardConfig,
    k: usize,
    m: usize,
    n: usize,
    block_oracle: impl Fn(usize, usize) -> Vec<f32>,
) -> Vec<f32> {
    let leaves: Vec<Vec<f32>> = (0..shards.n_live(k))
        .map(|s| {
            let (k0, k1) = shards.shard_span(k, s);
            block_oracle(k0, k1)
        })
        .collect();
    let mut want = pairwise_tree(leaves);
    want.resize(m * n, 0.0);
    want
}

/// Sharded-reduction row: every [`conformance_shard_configs`] entry ×
/// every [`conformance_kernel_paths`] path × every thread count, on all
/// three formats, **clean and corrupted operands** — the engine must
/// match the independently built per-block decode-oracle reduction tree
/// bit-for-bit, and the 1-shard config is thereby pinned bitwise to the
/// classic unsharded oracle. Covers the degenerate configs (`n_shards` ∈
/// {1, k, > k}) at the table's degenerate depths (`k` = 0/1/odd) and at
/// shard boundaries that fall off the SIMD strip width.
fn check_sharded(
    rng: &mut Xoshiro256,
    m: usize,
    k: usize,
    n: usize,
    threads: &[usize],
) -> Result<(), String> {
    let mut plan = FaultPlan::new(rng.next_u64());
    let rb = k.div_ceil(2);
    let configs = conformance_shard_configs(k);

    // Forward INT4×INT4: packed A and packed B, full path sweep.
    let acts: Vec<f32> = (0..m * k).map(|_| rng.normal_ms_f32(0.0, 1.5)).collect();
    let wts: Vec<f32> = (0..n * k).map(|_| rng.normal_ms_f32(0.0, 0.5)).collect();
    let aq = UniformQuantizer::new(4, 2.5, UniformRounding::Rdn);
    let wq = UniformQuantizer::new(4, 1.5, UniformRounding::Rdn);
    let mut a = vec![0u8; m * rb];
    aq.encode_packed_matrix_into(&acts, m, k, &[], &mut a, rb);
    let mut b = vec![0u8; n * rb];
    wq.encode_packed_matrix_into(&wts, n, k, &[], &mut b, rb);
    let mut scratch = QgemmScratch::new();
    let mut out = vec![f32::NAN; m * n];
    for corrupt in [false, true] {
        if corrupt && !b.is_empty() {
            plan.flip_bits(&mut b, 1 + b.len() / 7);
        }
        let tag = if corrupt { "corrupt" } else { "clean" };
        for &shards in &configs {
            let want = sharded_oracle(shards, k, m, n, |k0, k1| {
                let ab = packed_block(&a, m, rb, k0 / 2, (k1 - k0).div_ceil(2));
                let bb = packed_block(&b, n, rb, k0 / 2, (k1 - k0).div_ceil(2));
                qgemm_int4_decode_oracle(&ab, &bb, m, k1 - k0, n)
            });
            if shards.is_single() {
                bits_check(
                    &format!("forward/{tag}/1-shard-vs-unsharded-oracle"),
                    &want,
                    &qgemm_int4_decode_oracle(&a, &b, m, k, n),
                )?;
            }
            for path in conformance_kernel_paths() {
                for &t in threads {
                    out.fill(f32::NAN);
                    qgemm_int4_sharded_mt_with_path(
                        &a, &b, m, k, n, &mut out, t, &mut scratch, path, shards,
                    );
                    bits_check(
                        &format!("forward/{tag}/s{}/{}[{t}]", shards.n_shards(), path.label()),
                        &out,
                        &want,
                    )?;
                }
            }
            out.fill(f32::NAN);
            qgemm_int4_sharded_mt_with(&a, &b, m, k, n, &mut out, 2, &mut scratch, shards);
            bits_check(&format!("forward/{tag}/s{}/auto", shards.n_shards()), &out, &want)?;
        }
    }

    // Radix-4 TPR: typed A codes, packed B, full path sweep.
    let ac = random_codes(rng, m * k);
    let g: Vec<f32> = (0..n * k).map(|_| rng.signed_lognormal_f32(0.0, 3.0)).collect();
    let r4 = Radix4Quantizer::new(Radix4Format::FP4);
    let mut br = vec![0u8; n * rb];
    r4.encode_packed_matrix_into(&g, n, k, TprPhase::Base, &mut br, rb);
    for corrupt in [false, true] {
        if corrupt && !br.is_empty() {
            plan.flip_bits(&mut br, 1 + br.len() / 7);
        }
        let tag = if corrupt { "corrupt" } else { "clean" };
        for &shards in &configs {
            let want = sharded_oracle(shards, k, m, n, |k0, k1| {
                let ab = codes_block(&ac, m, k, k0, k1);
                let bb = packed_block(&br, n, rb, k0 / 2, (k1 - k0).div_ceil(2));
                qgemm_radix4_decode_oracle(&ab, &bb, m, k1 - k0, n)
            });
            for path in conformance_kernel_paths() {
                for &t in threads {
                    out.fill(f32::NAN);
                    qgemm_radix4_sharded_mt_with_path(
                        &ac, &br, m, k, n, &mut out, t, &mut scratch, path, shards,
                    );
                    bits_check(
                        &format!("radix4/{tag}/s{}/{}[{t}]", shards.n_shards(), path.label()),
                        &out,
                        &want,
                    )?;
                }
            }
            out.fill(f32::NAN);
            qgemm_radix4_sharded_mt_with(&ac, &br, m, k, n, &mut out, 2, &mut scratch, shards);
            bits_check(&format!("radix4/{tag}/s{}/auto", shards.n_shards()), &out, &want)?;
        }
    }

    // Backward INT4×FP4 (gather-only by the MF-BPROP contract): typed A
    // codes against packed LUQ gradient codes.
    let gq: Vec<f32> = (0..n * k).map(|_| rng.signed_lognormal_f32(0.0, 2.0)).collect();
    let mut noise = vec![0.0f32; n * k];
    rng.fill_uniform(&mut noise);
    let q = LogQuantizer::new(LogQuantConfig::luq(LogFormat::FP4));
    let mut bq = vec![0u8; n * rb];
    q.quantize_to_codes_matrix_into(&gq, n, k, &noise, &mut bq, rb);
    for corrupt in [false, true] {
        if corrupt && !bq.is_empty() {
            plan.flip_bits(&mut bq, 1 + bq.len() / 7);
        }
        let tag = if corrupt { "corrupt" } else { "clean" };
        for &shards in &configs {
            let want = sharded_oracle(shards, k, m, n, |k0, k1| {
                let ab = codes_block(&ac, m, k, k0, k1);
                let bb = packed_block(&bq, n, rb, k0 / 2, (k1 - k0).div_ceil(2));
                qgemm_decode_oracle(&ab, &bb, m, k1 - k0, n)
            });
            for &t in threads {
                out.fill(f32::NAN);
                qgemm_packed_sharded_mt_with(&ac, &bq, m, k, n, &mut out, t, &mut scratch, shards);
                bits_check(&format!("backward/{tag}/s{}[{t}]", shards.n_shards()), &out, &want)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite: the one table-driven cross-format suite — all three LUT
    /// formats × edge + randomized shapes × thread counts
    /// {1, 2, num_cpus}, bit-exact vs each format's decode oracle.
    #[test]
    fn cross_format_qgemm_conformance() {
        run_conformance(0xC04F, 10);
    }

    /// The harness itself covers what it claims: every engine format has
    /// a table row, the thread list starts at 1 and is strictly
    /// increasing, and the edge-shape list hits each degenerate
    /// dimension.
    #[test]
    fn conformance_table_covers_formats_threads_and_edges() {
        let names: Vec<&str> = conformance_formats().iter().map(|f| f.name).collect();
        assert_eq!(
            names,
            vec![
                "backward-int4xfp4",
                "forward-int4xint4",
                "radix4-tpr",
                "corrupted-operand",
                "forward-format-layer-step",
                "sharded-reduction",
                "step-profile",
            ]
        );
        let threads = conformance_thread_counts();
        assert_eq!(threads[0], 1);
        assert!(threads.windows(2).all(|w| w[0] < w[1]), "{threads:?}");
        let shapes = conformance_edge_shapes();
        assert!(shapes.iter().any(|&(m, _, _)| m == 0), "missing m = 0");
        assert!(shapes.iter().any(|&(_, _, n)| n == 0), "missing n = 0");
        assert!(shapes.iter().any(|&(_, k, _)| k == 0), "missing k = 0");
        assert!(shapes.iter().any(|&(_, k, _)| k % 2 == 1), "missing odd k");
        assert!(shapes.iter().any(|&(m, _, n)| m == 1 && n == 1), "missing 1x1");
        let paths = conformance_kernel_paths();
        assert!(paths.contains(&KernelPath::Scalar), "scalar oracle missing");
        assert!(paths.contains(&KernelPath::Portable), "portable path missing");
        assert!(paths.iter().all(|p| p.is_available()), "{paths:?}");
    }

    /// The shard-config sweep covers the degenerate corners the tier-2
    /// contract calls out: unsharded, `k` shards, beyond-`k` shards, and
    /// the env override (single on unset hosts, so the list is valid
    /// under any `QGEMM_SHARDS` value the CI matrix pins).
    #[test]
    fn conformance_shard_configs_cover_degenerate_corners() {
        for k in [0usize, 1, 7, 33, 64] {
            let configs = conformance_shard_configs(k);
            assert!(configs.iter().any(|c| c.is_single()), "k={k}: unsharded row missing");
            assert!(
                configs.iter().any(|c| c.n_shards() == k.max(1)),
                "k={k}: n_shards = k row missing"
            );
            assert!(
                configs.iter().any(|c| c.n_shards() > k),
                "k={k}: n_shards > k row missing"
            );
            // Every listed config partitions [0, k) regardless of shard
            // count — empty trailing shards, never lost columns.
            for &c in &configs {
                let mut covered = 0;
                for s in 0..c.n_live(k) {
                    let (k0, k1) = c.shard_span(k, s);
                    assert_eq!(k0, covered, "gap before shard {s} of {c:?} at k={k}");
                    assert!(k1 > k0, "empty live shard {s} of {c:?} at k={k}");
                    covered = k1;
                }
                assert_eq!(covered, k, "{c:?} does not cover k={k}");
            }
        }
    }

    /// The step-profile sweep holds one entry per [`StepProfile`]
    /// constructor, with non-default knobs actually set (so the builder
    /// and TOML paths are exercised beyond the defaults they start from).
    #[test]
    fn conformance_step_profiles_cover_every_constructor() {
        let profiles = conformance_step_profiles();
        assert_eq!(profiles.len(), 3);
        assert_eq!(profiles[0], StepProfile::paper_default());
        assert_eq!(profiles[1].format(), ForwardFormat::Radix4Tpr);
        assert_eq!(profiles[1].shards().n_shards(), 3);
        assert_eq!(profiles[2].format(), ForwardFormat::Radix4Tpr);
        assert_eq!(profiles[2].kernel_path(), Some(KernelPath::Portable));
        assert_eq!(profiles[2].shards().n_shards(), 2);
    }

    /// The pairwise-tree reference folds like the engine promises: a
    /// known 5-leaf tree reduces as ((0+1)+(2+3))+4.
    #[test]
    fn pairwise_tree_reference_shape() {
        let leaves: Vec<Vec<f32>> = (0..5).map(|i| vec![10.0f32.powi(i)]).collect();
        let folded = pairwise_tree(leaves);
        let want = ((1.0f32 + 10.0) + (100.0 + 1000.0)) + 10000.0;
        assert_eq!(folded, vec![want]);
        assert!(pairwise_tree(Vec::new()).is_empty());
    }
}
