//! In-repo property-testing and statistical-assertion harness.
//!
//! The offline crate registry has no `proptest`/`quickcheck`, so this module
//! provides the small core we need: run a property over many seeded random
//! inputs, and on failure report the case index and seed so the exact case
//! can be replayed. Statistical assertions (`assert_mean_within`) wrap the
//! standard-error machinery used by the unbiasedness tests.

pub mod alloc_guard;
pub mod conformance;
pub mod fault;
#[cfg(test)]
mod fault_suite;

use crate::rng::Xoshiro256;

/// Run `prop` over `cases` random inputs drawn by `gen` from a seeded RNG.
/// On failure, panics with the case index, seed, and a debug rendering of
/// the failing input. This is the crate's property-test entry point.
pub fn prop_check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Xoshiro256) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {i}/{cases} (seed {seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Relative-or-absolute closeness, mirroring numpy's `allclose` semantics.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs()
}

/// Assert two f32 slices are elementwise close.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            close(x as f64, y as f64, rtol as f64, atol as f64),
            "mismatch at [{i}]: {x} vs {y} (rtol={rtol}, atol={atol})"
        );
    }
}

/// Sample mean and the standard error of the mean.
pub fn mean_sem(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
    (mean, (var / n).sqrt())
}

/// Assert a sample mean is within `k_sigma` standard errors of `target`.
/// Used by the unbiasedness property tests: for an unbiased quantizer the
/// empirical mean of `Q(x) - x` must be statistically indistinguishable
/// from zero.
pub fn assert_mean_within(xs: &[f64], target: f64, k_sigma: f64, context: &str) {
    let (mean, sem) = mean_sem(xs);
    let dev = (mean - target).abs();
    assert!(
        dev <= k_sigma * sem.max(1e-12),
        "{context}: mean {mean:.6e} deviates from {target:.6e} by {dev:.3e} > {k_sigma}*SEM ({sem:.3e}, n={})",
        xs.len()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_passes_trivial_property() {
        prop_check(
            "abs_nonneg",
            1,
            256,
            |rng| rng.normal_f32(),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always_fails`")]
    fn prop_check_reports_failures() {
        prop_check(
            "always_fails",
            1,
            4,
            |rng| rng.uniform_f32(),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn mean_sem_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let (m, s) = mean_sem(&xs);
        assert!((m - 2.5).abs() < 1e-12);
        // var = 5/3, sem = sqrt(5/3/4)
        assert!((s - (5.0f64 / 3.0 / 4.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn allclose_accepts_within_tol() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-6, 2.0 - 1e-6], 1e-5, 1e-8);
    }

    #[test]
    #[should_panic(expected = "mismatch at [1]")]
    fn allclose_rejects_outside_tol() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.1], 1e-5, 1e-8);
    }
}
