//! Counting global allocator: upgrades "allocation-free at steady state"
//! from a capacity-pinning argument into a hard zero-alloc assertion.
//!
//! The crate's unit-test binary (and only it — see the `#[cfg(test)]` on
//! the `#[global_allocator]` below) routes every heap call through
//! [`CountingAlloc`], which bumps **thread-local** counters and delegates
//! to [`System`]. Thread-locality matters twice over: the libtest harness
//! runs tests concurrently, so a global counter would pick up allocations
//! from unrelated tests; and the counters are `const`-initialized `Cell`s,
//! so reading them never allocates — a lazily-initialized thread-local
//! would recurse into the allocator it instruments.
//!
//! [`measure`] wraps a closure and returns the delta. It first runs a probe
//! allocation and panics loudly if the counting allocator is not installed
//! (integration tests and benches link the non-test build of this crate,
//! where `measure` would otherwise report zeros and vacuously pass).
//!
//! Zero-alloc assertions are only meaningful at `n_threads == 1`:
//! multithreaded layer steps spawn scoped threads, and spawning allocates
//! on the spawning thread by design.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static DEALLOCS: Cell<u64> = const { Cell::new(0) };
    static BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Allocation counts observed on the current thread.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocStats {
    /// Number of `alloc`/`alloc_zeroed`/`realloc` calls.
    pub allocs: u64,
    /// Number of `dealloc` calls.
    pub deallocs: u64,
    /// Total bytes requested across counted allocation calls.
    pub bytes: u64,
}

fn snapshot() -> AllocStats {
    AllocStats {
        allocs: ALLOCS.with(Cell::get),
        deallocs: DEALLOCS.with(Cell::get),
        bytes: BYTES.with(Cell::get),
    }
}

fn count_alloc(bytes: usize) {
    ALLOCS.with(|c| c.set(c.get() + 1));
    BYTES.with(|c| c.set(c.get() + bytes as u64));
}

/// `System`, with thread-local call counting bolted on.
pub struct CountingAlloc;

// SAFETY: every method delegates to System with unchanged arguments; counter
// bumps are plain thread-local stores, so System's contract is preserved.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds GlobalAlloc's contract; forwarded to System.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        count_alloc(layout.size());
        // SAFETY: same layout the caller vouched for.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: caller upholds GlobalAlloc's contract; forwarded to System.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        DEALLOCS.with(|c| c.set(c.get() + 1));
        // SAFETY: ptr/layout pair comes from a prior alloc on this allocator,
        // which always delegated to System.
        unsafe { System.dealloc(ptr, layout) }
    }

    // SAFETY: caller upholds GlobalAlloc's contract; forwarded to System.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        count_alloc(layout.size());
        // SAFETY: same layout the caller vouched for.
        unsafe { System.alloc_zeroed(layout) }
    }

    // SAFETY: caller upholds GlobalAlloc's contract; forwarded to System.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        count_alloc(new_size);
        // SAFETY: ptr/layout pair comes from a prior alloc on this allocator;
        // new_size is forwarded unchanged.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[cfg(test)]
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run `f` and return its result plus the allocation delta observed on this
/// thread. Panics if the counting allocator is not installed (i.e. when
/// called from anything but this crate's unit tests), so a hard zero-alloc
/// assertion can never pass vacuously.
pub fn measure<T>(f: impl FnOnce() -> T) -> (T, AllocStats) {
    let pre = snapshot();
    let probe = std::hint::black_box(Vec::<u8>::with_capacity(16));
    drop(probe);
    assert!(
        ALLOCS.with(Cell::get) > pre.allocs,
        "alloc_guard: counting allocator not installed — measure() is only meaningful in this \
         crate's unit tests (the #[cfg(test)] #[global_allocator])"
    );
    let before = snapshot();
    let out = f();
    let after = snapshot();
    (
        out,
        AllocStats {
            allocs: after.allocs - before.allocs,
            deallocs: after.deallocs - before.deallocs,
            bytes: after.bytes - before.bytes,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_allocator_registers_allocations() {
        // Guards against the allocator silently not being installed: a
        // fresh Vec must register exactly one allocation of >= its request.
        let (v, stats) = measure(|| std::hint::black_box(vec![0u8; 4096]));
        assert_eq!(v.len(), 4096);
        assert!(stats.allocs >= 1, "{stats:?}");
        assert!(stats.bytes >= 4096, "{stats:?}");
    }

    #[test]
    fn measure_sees_zero_for_alloc_free_code() {
        let mut acc = 0u64;
        let (_, stats) = measure(|| {
            for i in 0..1000u64 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            std::hint::black_box(acc)
        });
        assert_eq!(stats.allocs, 0, "{stats:?}");
        assert_eq!(stats.bytes, 0, "{stats:?}");
    }

    #[test]
    fn dealloc_is_counted() {
        let v = vec![1u8; 128];
        let (_, stats) = measure(|| drop(std::hint::black_box(v)));
        assert!(stats.deallocs >= 1, "{stats:?}");
    }
}
