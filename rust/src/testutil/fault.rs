//! Deterministic fault-injection harness for the numerical-fault
//! supervisor tests.
//!
//! Every fault the supervisor claims to detect must be *injectable on
//! demand and reproducible bit-for-bit*, or the fault suite degenerates
//! into flaky best-effort poking. A [`FaultPlan`] is a counter-based
//! Philox stream keyed by a single seed: the same seed replays the exact
//! same corruption sites — which bit of which packed nibble byte flips,
//! which activation turns NaN, how many draws the RNG stream slips, where
//! a checkpoint file is truncated — independently of platform or call
//! site. Tests log the seed; a failure replays with it.
//!
//! The plan is format-agnostic on purpose: it corrupts *representations*
//! (byte streams, f32 slices, noise streams, files), and the detection
//! tests assert what the supervisor stack makes of the damage.

use crate::rng::{NoiseSource, Philox4x32};
use std::fs;
use std::io;
use std::path::Path;

/// One injected bit flip: `bytes[byte] ^= mask`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitFlip {
    pub byte: usize,
    /// Single-bit mask (a power of two).
    pub mask: u8,
}

/// The three non-finite f32 poisons, cycled through by draw.
const POISONS: [f32; 3] = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY];

/// A seeded, replayable source of fault injections (see module docs).
pub struct FaultPlan {
    rng: Philox4x32,
}

impl FaultPlan {
    /// A plan keyed by `seed`; equal seeds inject identical faults.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { rng: Philox4x32::seed_from_u64(seed) }
    }

    /// Uniform index in `[0, n)` (Lemire multiply-shift, like the
    /// engines' own `uniform_usize`).
    fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.rng.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Flip one uniformly chosen bit of `bytes` (e.g. a packed nibble
    /// stream or a serialized checkpoint). Returns where, so a test can
    /// assert the damage landed in the lane it meant to hit.
    pub fn flip_bit(&mut self, bytes: &mut [u8]) -> BitFlip {
        assert!(!bytes.is_empty(), "cannot flip a bit in an empty buffer");
        let flip = BitFlip {
            byte: self.index(bytes.len()),
            mask: 1u8 << self.index(8),
        };
        bytes[flip.byte] ^= flip.mask;
        flip
    }

    /// Flip `n` (not necessarily distinct) bits.
    pub fn flip_bits(&mut self, bytes: &mut [u8], n: usize) -> Vec<BitFlip> {
        (0..n).map(|_| self.flip_bit(bytes)).collect()
    }

    /// Poison `n` uniformly chosen positions of `xs` with NaN/±Inf
    /// (activation/gradient corruption). Returns the poisoned indices.
    pub fn poison_f32(&mut self, xs: &mut [f32], n: usize) -> Vec<usize> {
        assert!(!xs.is_empty(), "cannot poison an empty slice");
        (0..n)
            .map(|_| {
                let at = self.index(xs.len());
                xs[at] = POISONS[self.index(POISONS.len())];
                at
            })
            .collect()
    }

    /// Desync a noise stream: consume 1..=4 draws from `rng` behind its
    /// owner's back. Returns how many were stolen.
    pub fn desync<R: NoiseSource>(&mut self, rng: &mut R) -> usize {
        let n = 1 + self.index(4);
        for _ in 0..n {
            rng.next_u64();
        }
        n
    }

    /// Truncate the file at `path` to a uniformly chosen proper prefix
    /// (a torn write / partial flush). Returns the new length.
    pub fn truncate_file(&mut self, path: &Path) -> io::Result<u64> {
        let len = fs::metadata(path)?.len();
        if len == 0 {
            return Ok(0);
        }
        let keep = self.index(len as usize) as u64;
        let f = fs::OpenOptions::new().write(true).open(path)?;
        f.set_len(keep)?;
        f.sync_all()?;
        Ok(keep)
    }

    /// Flip one uniformly chosen bit of the file at `path` in place
    /// (silent media corruption). Returns where.
    pub fn corrupt_file(&mut self, path: &Path) -> io::Result<BitFlip> {
        let mut bytes = fs::read(path)?;
        if bytes.is_empty() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "cannot corrupt an empty file",
            ));
        }
        let flip = self.flip_bit(&mut bytes);
        fs::write(path, &bytes)?;
        Ok(flip)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("luq_fault_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn plans_replay_bit_for_bit() {
        let mut a = FaultPlan::new(0xFA);
        let mut b = FaultPlan::new(0xFA);
        let mut buf_a = vec![0u8; 64];
        let mut buf_b = vec![0u8; 64];
        assert_eq!(a.flip_bits(&mut buf_a, 5), b.flip_bits(&mut buf_b, 5));
        assert_eq!(buf_a, buf_b);
        let mut xs_a = vec![1.0f32; 32];
        let mut xs_b = vec![1.0f32; 32];
        assert_eq!(a.poison_f32(&mut xs_a, 3), b.poison_f32(&mut xs_b, 3));
        for (x, y) in xs_a.iter().zip(xs_b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let mut ra = Xoshiro256::seed_from_u64(1);
        let mut rb = Xoshiro256::seed_from_u64(1);
        assert_eq!(a.desync(&mut ra), b.desync(&mut rb));
        assert_eq!(ra.next_u64(), rb.next_u64());
    }

    #[test]
    fn distinct_seeds_give_distinct_plans() {
        let mut a = FaultPlan::new(1);
        let mut b = FaultPlan::new(2);
        let same = (0..64)
            .filter(|_| {
                let mut ba = [0u8; 128];
                let mut bb = [0u8; 128];
                a.flip_bit(&mut ba) == b.flip_bit(&mut bb)
            })
            .count();
        assert!(same < 4, "plans from different seeds agree {same}/64 times");
    }

    #[test]
    fn flip_bit_changes_exactly_one_bit() {
        let mut plan = FaultPlan::new(7);
        for _ in 0..32 {
            let mut buf = vec![0xA5u8; 16];
            let flip = plan.flip_bit(&mut buf);
            assert_eq!(flip.mask.count_ones(), 1);
            assert_eq!(buf[flip.byte], 0xA5 ^ flip.mask);
            let touched = buf.iter().filter(|&&b| b != 0xA5).count();
            assert_eq!(touched, 1);
        }
    }

    #[test]
    fn poison_writes_nonfinite_values() {
        let mut plan = FaultPlan::new(9);
        let mut xs = vec![0.5f32; 20];
        let hits = plan.poison_f32(&mut xs, 6);
        assert_eq!(hits.len(), 6);
        for &i in &hits {
            assert!(!xs[i].is_finite(), "index {i} still finite: {}", xs[i]);
        }
        // Only the reported indices were touched.
        for (i, &x) in xs.iter().enumerate() {
            assert!(hits.contains(&i) || x == 0.5);
        }
    }

    #[test]
    fn desync_advances_the_victim_stream() {
        let mut plan = FaultPlan::new(11);
        let mut victim = Xoshiro256::seed_from_u64(3);
        let mut reference = Xoshiro256::seed_from_u64(3);
        let stolen = plan.desync(&mut victim);
        assert!((1..=4).contains(&stolen));
        for _ in 0..stolen {
            reference.next_u64();
        }
        assert_eq!(victim.next_u64(), reference.next_u64());
    }

    #[test]
    fn file_faults_truncate_and_corrupt() {
        let dir = tmpdir("file");
        let path = dir.join("victim.bin");
        let payload: Vec<u8> = (0..=255u8).collect();

        std::fs::write(&path, &payload).unwrap();
        let mut plan = FaultPlan::new(13);
        let kept = plan.truncate_file(&path).unwrap();
        assert!(kept < 256);
        let back = std::fs::read(&path).unwrap();
        assert_eq!(back, payload[..kept as usize]);

        std::fs::write(&path, &payload).unwrap();
        let flip = plan.corrupt_file(&path).unwrap();
        let back = std::fs::read(&path).unwrap();
        assert_eq!(back.len(), payload.len(), "corruption must not resize");
        assert_eq!(back[flip.byte], payload[flip.byte] ^ flip.mask);
        let diffs = back
            .iter()
            .zip(payload.iter())
            .filter(|(a, b)| a != b)
            .count();
        assert_eq!(diffs, 1);
        std::fs::remove_file(&path).ok();
    }
}
