//! The fault suite: every fault class the supervisor claims to handle,
//! injected deterministically (via [`super::fault::FaultPlan`]) and either
//! **detected within one step** or **proven benign**. All tests are
//! `fault_`-prefixed so `cargo test fault_` runs exactly this tier (CI's
//! fault-injection job does).
//!
//! Coverage map ([`FaultClass`] → evidence):
//! - `NonFinite`      — NaN/Inf poison in any operand: detected same-step,
//!   layer escalates ([`fault_nan_poison_detected_in_every_operand`]).
//! - `RngDesync`      — stolen draws between steps: detected on the next
//!   step ([`fault_rng_desync_detected_within_one_step`]).
//! - `UnderflowStorm` — near-total gradient underflow on real data
//!   ([`fault_underflow_storm_detected`]).
//! - `SaturationStorm`— collapsed hindsight scale clipping the majority
//!   ([`fault_saturation_storm_detected`]).
//! - `AlphaCollapse`  — cannot arise from the real pipeline (α = max|x| is
//!   positive whenever the tensor is nonzero), so the detector arm is
//!   driven directly with forged stats
//!   ([`fault_alpha_collapse_detector_trips_on_forged_stats`]).
//! - `CheckpointCorrupt` — any truncation and any single-bit flip of a
//!   v2 checkpoint fails the load
//!   ([`fault_checkpoint_truncation_always_fails_load`],
//!   [`fault_checkpoint_bitflip_always_fails_load`]), and the resulting
//!   verdict outranks every other fault class
//!   ([`fault_checkpoint_corruption_outranks_all_faults`]).
//! - Packed-stream bit flips — proven *benign* (finite, conformant):
//!   the total-decode test below plus the `corrupted-operand` row of
//!   [`super::conformance`], on every dispatchable [`KernelPath`]
//!   (scalar gather, portable nibble, AVX2 shuffle where available)
//!   ([`fault_kernel_paths_conformant_on_corrupted_operands`]).
//!
//! Plus the crash-safety contract: kill-at-any-step → resume from the
//! checkpoint is bit-identical to the uninterrupted run, on both noise
//! engines ([`fault_kill_and_resume_is_bit_identical`]); the K-sharded
//! layer-step row — tier-2 determinism across thread counts for fixed,
//! env-selected, and unsharded [`ShardConfig`]s, and same-step NaN
//! escalation under a sharded supervised step
//! ([`fault_sharded_layer_step_supervised_and_deterministic`]); and the
//! long-relapse window regression — doubling follows `min(2^cycle, cap)`
//! exactly, saturating at the cap without overshoot or overflow
//! ([`fault_supervisor_long_relapse_window_saturates_at_cap`]); and the
//! session-config intake gate — a malformed `[profile]` section is
//! rejected loudly at every surface (direct parse and serve job spec),
//! with the builder enforcing the same bounds
//! ([`fault_malformed_profile_is_loud_at_every_intake`]).

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::layer_step::{ForwardFormat, QuantizedLayerStep};
use crate::coordinator::supervisor::{
    StepPrecision, SupervisedLayerStep, Supervisor, SupervisorPolicy, Transition,
};
use crate::hw::mfbprop::{Fp4Code, Int4Code};
use crate::hw::qgemm::{
    int4_product_lut, product_lut, qgemm_int4_decode_oracle, qgemm_int4_mt_with_path,
    qgemm_radix4_decode_oracle, qgemm_radix4_mt_with_path, radix4_product_lut, KernelPath,
    QgemmScratch, ShardConfig,
};
use crate::quant::radix4::radix4_unit_value;
use crate::quant::{
    FaultClass, HealthConfig, LogFormat, LogQuantConfig, QuantStats, StepHealth,
};
use crate::rng::{NoiseEngine, NoiseSource, Xoshiro256};
use crate::runtime::HostTensor;
use crate::testutil::fault::FaultPlan;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("luq_fault_suite_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn layer_data(
    seed: u64,
    batch: usize,
    d_in: usize,
    d_out: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let acts = (0..batch * d_in).map(|_| rng.normal_ms_f32(0.0, 1.0)).collect();
    let wts = (0..d_out * d_in).map(|_| rng.normal_ms_f32(0.0, 0.4)).collect();
    let grads = (0..batch * d_out)
        .map(|_| rng.signed_lognormal_f32(0.0, 2.0))
        .collect();
    (acts, wts, grads)
}

/// Every 4-bit wire byte decodes to a finite, bounded value in both
/// nibble lanes under all three wire formats, and every product LUT entry
/// is finite — so a bit flip in any packed operand stream is *benign* at
/// the numeric level: it perturbs a value but cannot mint NaN/Inf or
/// panic. (Per-format value bounds: INT4 |v| ≤ 7, FP4 |v| ≤ 2⁶, radix-4
/// |v| ≤ 4⁶.)
#[test]
fn fault_total_decode_all_wire_bytes_is_benign() {
    for byte in 0..=255u8 {
        for nib in [byte & 0x0F, byte >> 4] {
            let i4 = Int4Code::from_nibble(nib).value();
            assert!(i4.is_finite() && i4.abs() <= 7.0, "int4 nibble {nib:#x}: {i4}");
            let f4 = Fp4Code::from_nibble(nib).value();
            assert!(f4.is_finite() && f4.abs() <= 64.0, "fp4 nibble {nib:#x}: {f4}");
            let r4 = radix4_unit_value(nib);
            assert!(
                r4.is_finite() && r4.abs() <= 4096.0,
                "radix4 nibble {nib:#x}: {r4}"
            );
        }
    }
    for (name, lut) in [
        ("backward", product_lut()),
        ("forward", int4_product_lut()),
        ("radix4", radix4_product_lut()),
    ] {
        for a in 0..16u8 {
            for b in 0..16u8 {
                let p = lut.product(a, b);
                assert!(p.is_finite(), "{name} lut[{a:#x}][{b:#x}] = {p}");
            }
        }
    }
}

/// Packed-stream corruption stays *conformant* on every dispatchable
/// kernel path: after bit flips in both packed operands, every
/// [`KernelPath`] — `Scalar` gather, `Portable` nibble loop, and `Avx2`
/// shuffle strips where the host has the feature — still produces
/// finite output bit-identical to the decode oracle *on the corrupted
/// bytes*, at 1 and 3 threads, for both integer formats. Same garbage in,
/// same garbage out, on every ISA path.
#[test]
fn fault_kernel_paths_conformant_on_corrupted_operands() {
    let (m, k, n) = (9usize, 33, 10);
    let rb = k.div_ceil(2);
    let mut rng = Xoshiro256::seed_from_u64(0x6B1D);
    let mut plan = FaultPlan::new(0x6B1E);
    let mut a: Vec<u8> = (0..m * rb).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
    let mut b: Vec<u8> = (0..n * rb).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
    plan.flip_bits(&mut a, 1 + a.len() / 5);
    plan.flip_bits(&mut b, 1 + b.len() / 5);
    let a_codes: Vec<Int4Code> =
        (0..m * k).map(|_| Int4Code::from_nibble((rng.next_u64() & 0xF) as u8)).collect();

    let int4_want = qgemm_int4_decode_oracle(&a, &b, m, k, n);
    let radix4_want = qgemm_radix4_decode_oracle(&a_codes, &b, m, k, n);
    for (name, want) in [("int4", &int4_want), ("radix4", &radix4_want)] {
        for (i, v) in want.iter().enumerate() {
            assert!(v.is_finite(), "{name} oracle[{i}] non-finite on corrupt bytes: {v}");
        }
    }

    let mut scratch = QgemmScratch::new();
    let mut out = vec![f32::NAN; m * n];
    for path in [KernelPath::Scalar, KernelPath::Portable, KernelPath::Avx2] {
        if !path.is_available() {
            continue;
        }
        for t in [1usize, 3] {
            out.fill(f32::NAN);
            qgemm_int4_mt_with_path(&a, &b, m, k, n, &mut out, t, &mut scratch, path);
            for (i, (g, w)) in out.iter().zip(int4_want.iter()).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "int4 {}/{t}T [{i}]: {g} vs oracle {w}",
                    path.label()
                );
            }
            out.fill(f32::NAN);
            qgemm_radix4_mt_with_path(&a_codes, &b, m, k, n, &mut out, t, &mut scratch, path);
            for (i, (g, w)) in out.iter().zip(radix4_want.iter()).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "radix4 {}/{t}T [{i}]: {g} vs oracle {w}",
                    path.label()
                );
            }
        }
    }
}

/// NaN/Inf poison injected into each operand (activations, weights,
/// gradients) is detected in the same step and escalates the layer.
#[test]
fn fault_nan_poison_detected_in_every_operand() {
    let (batch, d_in, d_out) = (5usize, 9, 6);
    let cfg = LogQuantConfig::luq(LogFormat::FP4);
    for victim in 0..3usize {
        let (mut acts, mut wts, mut grads) = layer_data(0xF0 + victim as u64, batch, d_in, d_out);
        let mut plan = FaultPlan::new(0x90 + victim as u64);
        let hit = match victim {
            0 => plan.poison_f32(&mut acts, 2),
            1 => plan.poison_f32(&mut wts, 2),
            _ => plan.poison_f32(&mut grads, 2),
        };
        assert!(!hit.is_empty());
        let mut sup = Supervisor::new(1, SupervisorPolicy::default());
        let mut step: SupervisedLayerStep = SupervisedLayerStep::new(cfg, 4);
        let mut rng = Xoshiro256::seed_from_u64(0x51);
        let out = step.step(
            &mut sup, 0, 0, &acts, &wts, &grads, batch, d_in, d_out, &mut rng, 1,
        );
        assert_eq!(
            out.health.worst(),
            Some(FaultClass::NonFinite),
            "operand {victim} poison not detected"
        );
        assert_eq!(out.transition, Some(Transition::Escalated));
        assert_eq!(sup.precision(0), StepPrecision::Fp32);
    }
}

/// NaN poison is caught under **both** forward formats — the sentinels
/// sit above the [`ForwardFormat`] choice, so the radix-4 TPR baseline
/// escalates exactly like the paper's LUQ pipeline.
#[test]
fn fault_nan_poison_detected_under_both_forward_formats() {
    let (batch, d_in, d_out) = (5usize, 9, 6);
    let cfg = LogQuantConfig::luq(LogFormat::FP4);
    for format in [ForwardFormat::Sawb, ForwardFormat::Radix4Tpr] {
        let (mut acts, wts, grads) = layer_data(0xF8, batch, d_in, d_out);
        let mut plan = FaultPlan::new(0x98);
        let hit = plan.poison_f32(&mut acts, 2);
        assert!(!hit.is_empty());
        let mut sup = Supervisor::new(1, SupervisorPolicy::default());
        let mut step: SupervisedLayerStep = SupervisedLayerStep::with_format(cfg, 4, format);
        let mut rng = Xoshiro256::seed_from_u64(0x58);
        let out = step.step(
            &mut sup, 0, 0, &acts, &wts, &grads, batch, d_in, d_out, &mut rng, 1,
        );
        assert_eq!(
            out.health.worst(),
            Some(FaultClass::NonFinite),
            "{format:?}: poison not detected"
        );
        assert_eq!(out.transition, Some(Transition::Escalated), "{format:?}");
        assert_eq!(sup.precision(0), StepPrecision::Fp32, "{format:?}");
    }
}

/// The K-sharded layer step keeps every supervision guarantee of the
/// unsharded one. A fixed multi-shard [`ShardConfig`] is deterministic
/// across thread counts (the tier-2 contract, here end-to-end through
/// forward + both backward GEMMs); the unsharded config reproduces the
/// default step bit-for-bit; the env-selected config (CI's
/// `QGEMM_SHARDS` matrix leg) is equally deterministic; and NaN poison
/// under a **sharded supervised** step still escalates same-step — the
/// sentinels sit above the sharding choice.
#[test]
fn fault_sharded_layer_step_supervised_and_deterministic() {
    let (batch, d_in, d_out) = (6usize, 33, 9);
    let cfg = LogQuantConfig::luq(LogFormat::FP4);
    let (acts, wts, grads) = layer_data(0xF9, batch, d_in, d_out);

    // Determinism per config: {unsharded, explicit 3-shard, env} × both
    // forward formats × thread counts {1, 3} — bitwise.
    for format in [ForwardFormat::Sawb, ForwardFormat::Radix4Tpr] {
        for shards in [ShardConfig::single(), ShardConfig::with_shards(3), ShardConfig::from_env()]
        {
            let mut runs = Vec::new();
            for n_threads in [1usize, 3] {
                let mut step: QuantizedLayerStep =
                    QuantizedLayerStep::with_format(cfg, 4, format);
                step.set_shards(shards);
                let mut rng = Xoshiro256::seed_from_u64(0x59);
                step.step(&acts, &wts, &grads, batch, d_in, d_out, &mut rng, n_threads);
                runs.push(
                    step.y()
                        .iter()
                        .chain(step.dx_t())
                        .chain(step.dw_t())
                        .map(|v| v.to_bits())
                        .collect::<Vec<u32>>(),
                );
            }
            assert_eq!(runs[0], runs[1], "{format:?} {shards:?}: thread count leaked");
        }
    }

    // Poison under a sharded supervised step: detected and escalated
    // exactly like the unsharded suite rows above.
    let (mut poisoned, wts2, grads2) = layer_data(0xFA, batch, d_in, d_out);
    let mut plan = FaultPlan::new(0x99);
    assert!(!plan.poison_f32(&mut poisoned, 2).is_empty());
    let mut sup = Supervisor::new(1, SupervisorPolicy::default());
    let mut step: SupervisedLayerStep = SupervisedLayerStep::new(cfg, 4);
    step.set_shards(ShardConfig::with_shards(3));
    let mut rng = Xoshiro256::seed_from_u64(0x5A);
    let out = step.step(
        &mut sup, 0, 0, &poisoned, &wts2, &grads2, batch, d_in, d_out, &mut rng, 3,
    );
    assert_eq!(out.health.worst(), Some(FaultClass::NonFinite), "sharded poison missed");
    assert_eq!(out.transition, Some(Transition::Escalated));
    assert_eq!(sup.precision(0), StepPrecision::Fp32);
}

/// Long-relapse regression for the window-doubling arithmetic: across
/// many escalate → readmit → relapse cycles the fallback window must
/// follow exactly `min(2^cycle, cap)` — doubling saturates **at** the
/// cap on the boundary cycle and stays pinned there, never overshooting
/// (the readmission off-by-one) and never wrapping (the overflow the
/// saturating multiply guards).
#[test]
fn fault_supervisor_long_relapse_window_saturates_at_cap() {
    let cap = 8usize;
    let mut sup = Supervisor::new(
        1,
        SupervisorPolicy {
            fallback_steps: 1,
            probation_steps: 1,
            max_fallback_steps: cap,
            ..SupervisorPolicy::default()
        },
    );
    let faulty = {
        let mut h = StepHealth::healthy();
        h.note(FaultClass::NonFinite);
        h
    };
    let mut step = 0u64;
    let mut observe = |sup: &mut Supervisor, h: &StepHealth| {
        let t = sup.observe(0, step, h);
        step += 1;
        t
    };

    assert_eq!(observe(&mut sup, &faulty), Some(Transition::Escalated));
    for cycle in 0..12u32 {
        // Serve the current fallback window: readmission must land after
        // exactly min(2^cycle, cap) healthy steps — not one more, not
        // one fewer.
        let want = (1usize << cycle.min(16)).min(cap);
        let mut served = 0usize;
        loop {
            let t = observe(&mut sup, &StepHealth::healthy());
            served += 1;
            if t == Some(Transition::Readmitted) {
                break;
            }
            assert!(served <= cap, "cycle {cycle}: window exceeded the cap");
        }
        assert_eq!(served, want, "cycle {cycle}: wrong fallback window");
        // Relapse on the single probation step: the window doubles,
        // saturating at the cap.
        assert_eq!(observe(&mut sup, &faulty), Some(Transition::Relapsed));
    }
}

/// `AlphaCollapse` cannot arise from the real pipeline (α = max|x| is
/// positive whenever the tensor is nonzero), so the detector arm is
/// injected directly: forged stats with a nonzero tensor and a degenerate
/// scale must trip exactly [`FaultClass::AlphaCollapse`], while a zero
/// tensor with α = 0 stays healthy.
#[test]
fn fault_alpha_collapse_detector_trips_on_forged_stats() {
    let cfg = HealthConfig::default();
    let mut health = StepHealth::healthy();
    cfg.assess_gemm(
        &QuantStats { max_abs: 3.0, alpha: 0.0, frac_underflow: 0.0, frac_clipped: 0.0 },
        &mut health,
    );
    assert_eq!(health.worst(), Some(FaultClass::AlphaCollapse));
    let mut health = StepHealth::healthy();
    cfg.assess_gemm(
        &QuantStats { max_abs: 0.0, alpha: 0.0, frac_underflow: 0.0, frac_clipped: 0.0 },
        &mut health,
    );
    assert!(health.is_healthy(), "zero tensor with α = 0 is legitimate");
}

/// An RNG stream desynced by a fault plan between supervised steps is
/// flagged `RngDesync` on the very next step.
#[test]
fn fault_rng_desync_detected_within_one_step() {
    let (batch, d_in, d_out) = (4usize, 7, 5);
    let (acts, wts, grads) = layer_data(0xD5, batch, d_in, d_out);
    let cfg = LogQuantConfig::luq(LogFormat::FP4);
    let mut sup = Supervisor::new(1, SupervisorPolicy::default());
    let mut step: SupervisedLayerStep = SupervisedLayerStep::new(cfg, 4);
    let mut rng = Xoshiro256::seed_from_u64(0x52);
    let out = step.step(
        &mut sup, 0, 0, &acts, &wts, &grads, batch, d_in, d_out, &mut rng, 1,
    );
    assert!(out.health.is_healthy());

    let mut plan = FaultPlan::new(0xDE);
    plan.desync(&mut rng);
    let out = step.step(
        &mut sup, 0, 1, &acts, &wts, &grads, batch, d_in, d_out, &mut rng, 1,
    );
    assert!(
        out.health.faults().contains(&FaultClass::RngDesync),
        "desync not detected: {:?}",
        out.health
    );
    assert_eq!(out.transition, Some(Transition::Escalated));
}

/// Real-data underflow storm: one enormous gradient element drives α so
/// high that every other element lands below the smallest representable
/// magnitude — `frac_underflow` ≥ 0.999 trips the sentinel.
#[test]
fn fault_underflow_storm_detected() {
    let (batch, d_in, d_out) = (4usize, 6, 256);
    let (acts, wts, mut grads) = layer_data(0xF5, batch, d_in, d_out);
    for g in grads.iter_mut() {
        *g = 1e-20 * g.signum();
    }
    grads[0] = 1e20;
    let cfg = LogQuantConfig::luq(LogFormat::FP4);
    let mut sup = Supervisor::new(1, SupervisorPolicy::default());
    let mut step: SupervisedLayerStep = SupervisedLayerStep::new(cfg, 4);
    let mut rng = Xoshiro256::seed_from_u64(0x53);
    let out = step.step(
        &mut sup, 0, 0, &acts, &wts, &grads, batch, d_in, d_out, &mut rng, 1,
    );
    assert!(
        out.health.faults().contains(&FaultClass::UnderflowStorm),
        "underflow storm not detected: {:?} (stats {:?})",
        out.health,
        out.stats
    );
    assert_eq!(out.transition, Some(Transition::Escalated));
}

/// Real-data saturation storm: a collapsed hindsight scale estimate
/// (FixedMax far below the data) clips the majority of gradient elements
/// — `frac_clipped` ≥ 0.5 trips the sentinel.
#[test]
fn fault_saturation_storm_detected() {
    let (batch, d_in, d_out) = (6usize, 8, 64);
    let (acts, wts, grads) = layer_data(0xFA, batch, d_in, d_out);
    // Median |g| of signed-lognormal(0, 2) is 1, so an estimate of 1e-6
    // puts essentially every element above the representable top.
    let cfg = LogQuantConfig::luq_hindsight(LogFormat::FP4, 1e-6);
    let mut sup = Supervisor::new(1, SupervisorPolicy::default());
    let mut step: SupervisedLayerStep = SupervisedLayerStep::new(cfg, 4);
    let mut rng = Xoshiro256::seed_from_u64(0x54);
    let out = step.step(
        &mut sup, 0, 0, &acts, &wts, &grads, batch, d_in, d_out, &mut rng, 1,
    );
    assert!(
        out.health.faults().contains(&FaultClass::SaturationStorm),
        "saturation storm not detected: {:?} (stats {:?})",
        out.health,
        out.stats
    );
    assert_eq!(out.transition, Some(Transition::Escalated));
}

fn sample_checkpoint() -> Checkpoint {
    let mut rng = NoiseEngine::Philox.seed_rng(0xCC);
    for _ in 0..5 {
        rng.next_u64();
    }
    Checkpoint::new(
        17,
        vec![
            HostTensor::f32(vec![3, 4], (0..12).map(|i| i as f32 * 0.5 - 3.0).collect()),
            HostTensor::i32(vec![5], vec![1, -2, 3, -4, 5]),
        ],
    )
    .with_rng(&rng)
}

/// Every proper prefix of a checkpoint file fails to load: there is no
/// truncation point — header or payload, aligned or not — that yields a
/// silently-wrong checkpoint.
#[test]
fn fault_checkpoint_truncation_always_fails_load() {
    let dir = tmpdir("trunc");
    let path = dir.join("base.ckpt");
    sample_checkpoint().save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let victim = dir.join("cut.ckpt");

    // Deterministic fault-plan cuts plus every boundary-adjacent length.
    let mut plan = FaultPlan::new(0x7C);
    std::fs::write(&victim, &bytes).unwrap();
    let mut cuts: Vec<u64> = (0..24).map(|_| plan.truncate_file(&victim).unwrap()).collect();
    cuts.extend([0, 7, 8, 15, 16, 19, 20, bytes.len() as u64 - 1]);
    for cut in cuts {
        std::fs::write(&victim, &bytes[..cut as usize]).unwrap();
        assert!(
            Checkpoint::load(&victim).is_err(),
            "truncation to {cut}/{} bytes loaded successfully",
            bytes.len()
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Any single-bit flip anywhere in a checkpoint file fails the load: the
/// magic, length-sanity, total-size, header-CRC, and per-tensor-CRC
/// checks jointly cover every byte.
#[test]
fn fault_checkpoint_bitflip_always_fails_load() {
    let dir = tmpdir("flip");
    let path = dir.join("base.ckpt");
    sample_checkpoint().save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let victim = dir.join("flip.ckpt");

    // 96 fault-plan flips, plus one flip in every fixed-prefix byte
    // (magic, header length, header CRC) where single-point parsing
    // decisions live.
    let mut plan = FaultPlan::new(0xB1);
    let mut flips: Vec<(usize, u8)> = Vec::new();
    for _ in 0..96 {
        let mut copy = bytes.clone();
        let f = plan.flip_bit(&mut copy);
        flips.push((f.byte, f.mask));
    }
    flips.extend((0..20).map(|b| (b, 0x10u8)));
    for (byte, mask) in flips {
        let mut copy = bytes.clone();
        copy[byte] ^= mask;
        std::fs::write(&victim, &copy).unwrap();
        assert!(
            Checkpoint::load(&victim).is_err(),
            "bit flip at byte {byte} mask {mask:#04x} loaded successfully"
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

/// The verdict a failed checkpoint load files upstream —
/// [`FaultClass::CheckpointCorrupt`] — outranks every other fault class,
/// so a corrupt resume halts instead of blending into a precision
/// fallback.
#[test]
fn fault_checkpoint_corruption_outranks_all_faults() {
    for other in [
        FaultClass::UnderflowStorm,
        FaultClass::SaturationStorm,
        FaultClass::AlphaCollapse,
        FaultClass::RngDesync,
        FaultClass::NonFinite,
    ] {
        let mut verdict = StepHealth::healthy();
        verdict.note(other);
        verdict.note(FaultClass::CheckpointCorrupt);
        assert_eq!(
            verdict.worst(),
            Some(FaultClass::CheckpointCorrupt),
            "{other:?} outranked CheckpointCorrupt"
        );
    }
}

/// One toy supervised-format training step: quantized layer step plus an
/// SGD update of the weights from dWᵀ. Data is derived from the step
/// index only, so the noise engine under test owns the whole stochastic
/// state.
fn toy_step(
    step: &mut QuantizedLayerStep<crate::rng::EngineRng>,
    weights: &mut [f32],
    step_idx: u64,
    rng: &mut crate::rng::EngineRng,
    batch: usize,
    d_in: usize,
    d_out: usize,
) {
    let (acts, _, grads) = layer_data(0xDA7A ^ step_idx, batch, d_in, d_out);
    step.step(&acts, weights, &grads, batch, d_in, d_out, rng, 1);
    let dw_t = step.dw_t();
    for o in 0..d_out {
        for i in 0..d_in {
            weights[o * d_in + i] -= 0.01 * dw_t[i * d_out + o];
        }
    }
}

/// Crash-safety: training for N steps equals training to step k, saving a
/// checkpoint (weights + step + RNG position), "dying", resuming from the
/// file, and finishing — bit-for-bit in the weights *and* in the noise
/// stream position, on both engines, for several kill points.
#[test]
fn fault_kill_and_resume_is_bit_identical() {
    let (batch, d_in, d_out) = (4usize, 6, 5);
    let total_steps = 8u64;
    let cfg = LogQuantConfig::luq(LogFormat::FP4);
    let dir = tmpdir("resume");
    for engine in [NoiseEngine::Philox, NoiseEngine::Xoshiro] {
        // Uninterrupted reference run.
        let (_, w0, _) = layer_data(0x3EED, batch, d_in, d_out);
        let mut w_ref = w0.clone();
        let mut rng_ref = engine.seed_rng(0xBEEF);
        let mut step_ref: QuantizedLayerStep<crate::rng::EngineRng> =
            QuantizedLayerStep::new(cfg, 4);
        for s in 0..total_steps {
            toy_step(&mut step_ref, &mut w_ref, s, &mut rng_ref, batch, d_in, d_out);
        }

        for kill_at in [1u64, 4, 7] {
            let path = dir.join(format!("{}_{kill_at}.ckpt", engine.name()));
            // Run to the kill point and checkpoint.
            let mut w = w0.clone();
            let mut rng = engine.seed_rng(0xBEEF);
            let mut lstep: QuantizedLayerStep<crate::rng::EngineRng> =
                QuantizedLayerStep::new(cfg, 4);
            for s in 0..kill_at {
                toy_step(&mut lstep, &mut w, s, &mut rng, batch, d_in, d_out);
            }
            Checkpoint::new(kill_at, vec![HostTensor::f32(vec![d_out, d_in], w)])
                .with_rng(&rng)
                .save(&path)
                .unwrap();
            // "Kill": everything dropped; resume purely from the file.
            drop(rng);
            drop(lstep);
            let back = Checkpoint::load(&path).unwrap();
            assert_eq!(back.step, kill_at);
            let mut w = back.tensors[0].as_f32().unwrap().to_vec();
            let mut rng = back.rng.as_ref().unwrap().restore().unwrap();
            let mut lstep: QuantizedLayerStep<crate::rng::EngineRng> =
                QuantizedLayerStep::new(cfg, 4);
            for s in back.step..total_steps {
                toy_step(&mut lstep, &mut w, s, &mut rng, batch, d_in, d_out);
            }
            for (i, (a, b)) in w.iter().zip(w_ref.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{engine:?} kill@{kill_at}: weight {i} diverged ({a} vs {b})"
                );
            }
            assert_eq!(
                rng.next_u64(),
                rng_ref.clone().next_u64(),
                "{engine:?} kill@{kill_at}: stream position diverged"
            );
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Configuration faults are loud at every intake surface. A malformed
/// `[profile]` section fails [`StepProfile::from_toml_section`] and the
/// serve job deserializer with a pointed error — never a silent
/// fall-back to defaults — and the programmatic constructors enforce
/// the same invariant: the builder's `build` rejects out-of-range bit
/// widths while [`StepProfile::paper_default`] always satisfies its own
/// validation. A bad session config must die at the door, because past
/// admission every layer above the kernels trusts the profile blindly.
///
/// [`StepProfile::from_toml_section`]: crate::coordinator::profile::StepProfile::from_toml_section
/// [`StepProfile::paper_default`]: crate::coordinator::profile::StepProfile::paper_default
#[test]
fn fault_malformed_profile_is_loud_at_every_intake() {
    use crate::config::toml::parse_toml;
    use crate::coordinator::profile::StepProfile;
    use crate::coordinator::serve::JobSpec;

    for (bad, needle) in [
        ("[profile]\nbits = 9\n", "bits"),
        ("[profile]\nformat = \"fp32\"\n", "format"),
        ("[profile]\nshards = 0\n", "shards"),
        ("[profile]\nkernel_path = \"sse9\"\n", "kernel_path"),
        ("[profile]\nnoise_engine = \"mt19937\"\n", "noise_engine"),
        ("[profile]\nunknown_knob = 1\n", "unknown"),
    ] {
        let section = parse_toml(bad).unwrap().remove("profile").unwrap();
        let err = StepProfile::from_toml_section(&section).unwrap_err();
        assert!(
            err.contains(needle),
            "section error for {bad:?} is not pointed: {err}"
        );
        let job = format!("[job]\nlayers = [2, 3, 2]\n{bad}");
        let err = JobSpec::from_toml(&job).unwrap_err();
        assert!(
            err.contains(needle),
            "job-spec error for {bad:?} is not pointed: {err}"
        );
    }

    // Programmatic intakes enforce the same invariant.
    assert!(StepProfile::builder().bits(1).build().is_err());
    assert!(StepProfile::builder().bits(5).build().is_err());
    let p = StepProfile::paper_default();
    assert_eq!(p, StepProfile::builder().build().unwrap());
}
