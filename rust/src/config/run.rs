//! Typed run configuration — the schema of the `configs/*.toml` files and
//! the single source of truth the coordinator trains from.

use super::toml::{parse_toml, TomlValue};
use crate::coordinator::profile::StepProfile;
use std::collections::BTreeMap;

/// Which model family an experiment trains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Decoder-only transformer LM on the token corpus.
    Transformer,
    /// Small conv net on the Gaussian-mixture images.
    Cnn,
    /// Plain MLP (fast CI-scale experiments).
    Mlp,
}

impl ModelKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "transformer" => Ok(ModelKind::Transformer),
            "cnn" => Ok(ModelKind::Cnn),
            "mlp" => Ok(ModelKind::Mlp),
            _ => Err(format!("unknown model kind `{s}`")),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Transformer => "transformer",
            ModelKind::Cnn => "cnn",
            ModelKind::Mlp => "mlp",
        }
    }
}

/// Backward (neural-gradient) quantization scheme — the Table 1 / Fig. 3
/// axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BwdQuantScheme {
    /// Full precision (baseline).
    Fp32,
    /// LUQ (paper §4).
    Luq,
    /// Naive FP4 (Fig. 3 ablation).
    NaiveFp4,
    /// Naive + stochastic pruning.
    NaiveSp,
    /// Naive + RDNP.
    NaiveRdnp,
    /// SP + RDNP without the exact-max scale.
    SpRdnp,
    /// Ultra-low radix-4 with two-phase rounding (Sun et al. 2020).
    UltraLow,
    /// Uniform INT4 with SR (the Fig. 1c "SR" arm on the backward pass).
    IntSr,
    /// Uniform INT4 with RDN (the Fig. 1c "RDN" arm).
    IntRdn,
}

impl BwdQuantScheme {
    pub fn parse(s: &str) -> Result<Self, String> {
        Ok(match s {
            "fp32" => Self::Fp32,
            "luq" => Self::Luq,
            "naive" => Self::NaiveFp4,
            "naive_sp" => Self::NaiveSp,
            "naive_rdnp" => Self::NaiveRdnp,
            "sp_rdnp" => Self::SpRdnp,
            "ultralow" => Self::UltraLow,
            "int_sr" => Self::IntSr,
            "int_rdn" => Self::IntRdn,
            _ => return Err(format!("unknown bwd quant scheme `{s}`")),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            Self::Fp32 => "fp32",
            Self::Luq => "luq",
            Self::NaiveFp4 => "naive",
            Self::NaiveSp => "naive_sp",
            Self::NaiveRdnp => "naive_rdnp",
            Self::SpRdnp => "sp_rdnp",
            Self::UltraLow => "ultralow",
            Self::IntSr => "int_sr",
            Self::IntRdn => "int_rdn",
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct ModelConfig {
    pub kind: ModelKind,
    /// Width (transformer d_model / CNN base channels / MLP hidden).
    pub dim: usize,
    pub depth: usize,
    /// Transformer-only: attention heads.
    pub heads: usize,
    /// Transformer-only: sequence length.
    pub seq_len: usize,
    /// Vocab (transformer) or classes (cnn/mlp).
    pub vocab: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct QuantConfig {
    /// Forward (weights+activations) bits; 0 disables forward quantization.
    pub fwd_bits: u32,
    /// Forward rounding: true = SR (Fig. 1b ablation arm), false = RDN.
    pub fwd_stochastic: bool,
    pub bwd: BwdQuantScheme,
    /// Backward exponent bits (3 for FP4, 1 for FP2, 2 for FP3).
    pub bwd_exp_bits: u32,
    /// SMP samples (1 = off).
    pub smp_samples: usize,
    /// Use hindsight max estimation (Eq. 24) instead of measured max.
    pub hindsight: bool,
    /// Hindsight momentum η.
    pub hindsight_eta: f32,
    /// Noise re-use period in iterations (Fig. 4; 1 = fresh noise).
    pub noise_reuse: usize,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            fwd_bits: 4,
            fwd_stochastic: false,
            bwd: BwdQuantScheme::Luq,
            bwd_exp_bits: 3,
            smp_samples: 1,
            hindsight: false,
            hindsight_eta: 0.1,
            noise_reuse: 1,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// LR decay factor applied at each milestone (paper: 0.1 @ 30/60/80).
    pub lr_decay: f32,
    /// Milestones as fractions of total steps.
    pub lr_milestones: [f32; 3],
    pub eval_every: usize,
    pub eval_batches: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            batch: 32,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 1e-4,
            lr_decay: 0.1,
            lr_milestones: [0.33, 0.66, 0.89],
            eval_every: 50,
            eval_batches: 4,
            seed: 1,
        }
    }
}

/// FNT fine-tuning phase (paper §4.2, Eq. 23).
#[derive(Clone, Copy, Debug)]
pub struct FntConfig {
    /// Fine-tune steps T (0 = disabled).
    pub steps: usize,
    /// Peak LR of the triangular schedule (paper: 1e-3).
    pub lr_base: f32,
}

impl Default for FntConfig {
    fn default() -> Self {
        FntConfig { steps: 0, lr_base: 1e-3 }
    }
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub name: String,
    pub model: ModelConfig,
    pub quant: QuantConfig,
    pub train: TrainConfig,
    pub fnt: FntConfig,
    /// Step-execution profile (`[profile]` section) — format, bits,
    /// shards, kernel path, noise engine; the same schema serve job
    /// specs embed. Defaults to [`StepProfile::paper_default`].
    pub profile: StepProfile,
    /// Output directory for JSONL logs.
    pub out_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            name: "default".into(),
            model: ModelConfig {
                kind: ModelKind::Mlp,
                dim: 128,
                depth: 2,
                heads: 4,
                seq_len: 64,
                vocab: 256,
            },
            quant: QuantConfig::default(),
            train: TrainConfig::default(),
            fnt: FntConfig::default(),
            profile: StepProfile::paper_default(),
            out_dir: "runs".into(),
        }
    }
}

fn take<'a>(
    t: &'a BTreeMap<String, TomlValue>,
    used: &mut Vec<String>,
    key: &str,
) -> Option<&'a TomlValue> {
    used.push(key.to_string());
    t.get(key)
}

macro_rules! set_num {
    ($cfg:expr, $t:expr, $used:expr, $key:literal, $as:ident, $ty:ty) => {
        if let Some(v) = take($t, $used, $key) {
            $cfg = v
                .$as()
                .ok_or_else(|| format!("`{}` has wrong type", $key))? as $ty;
        }
    };
}

fn check_unknown(
    table: &BTreeMap<String, TomlValue>,
    used: &[String],
    section: &str,
) -> Result<(), String> {
    for k in table.keys() {
        if !used.iter().any(|u| u == k) {
            return Err(format!("unknown key `{k}` in section [{section}]"));
        }
    }
    Ok(())
}

impl RunConfig {
    /// Parse from TOML text, starting from defaults; rejects unknown keys.
    pub fn from_toml(src: &str) -> Result<RunConfig, String> {
        let doc = parse_toml(src)?;
        let mut cfg = RunConfig::default();
        let empty = BTreeMap::new();

        let top = doc.get("").unwrap_or(&empty);
        let mut used = vec![];
        if let Some(v) = take(top, &mut used, "name") {
            cfg.name = v.as_str().ok_or("`name` must be a string")?.to_string();
        }
        if let Some(v) = take(top, &mut used, "out_dir") {
            cfg.out_dir = v.as_str().ok_or("`out_dir` must be a string")?.to_string();
        }
        check_unknown(top, &used, "")?;

        if let Some(t) = doc.get("model") {
            let mut used = vec![];
            if let Some(v) = take(t, &mut used, "kind") {
                cfg.model.kind = ModelKind::parse(v.as_str().ok_or("`kind` must be a string")?)?;
            }
            set_num!(cfg.model.dim, t, &mut used, "dim", as_int, usize);
            set_num!(cfg.model.depth, t, &mut used, "depth", as_int, usize);
            set_num!(cfg.model.heads, t, &mut used, "heads", as_int, usize);
            set_num!(cfg.model.seq_len, t, &mut used, "seq_len", as_int, usize);
            set_num!(cfg.model.vocab, t, &mut used, "vocab", as_int, usize);
            check_unknown(t, &used, "model")?;
        }

        if let Some(t) = doc.get("quant") {
            let mut used = vec![];
            set_num!(cfg.quant.fwd_bits, t, &mut used, "fwd_bits", as_int, u32);
            if let Some(v) = take(t, &mut used, "fwd_stochastic") {
                cfg.quant.fwd_stochastic = v.as_bool().ok_or("`fwd_stochastic` must be bool")?;
            }
            if let Some(v) = take(t, &mut used, "bwd") {
                cfg.quant.bwd = BwdQuantScheme::parse(v.as_str().ok_or("`bwd` must be a string")?)?;
            }
            set_num!(cfg.quant.bwd_exp_bits, t, &mut used, "bwd_exp_bits", as_int, u32);
            set_num!(cfg.quant.smp_samples, t, &mut used, "smp_samples", as_int, usize);
            if let Some(v) = take(t, &mut used, "hindsight") {
                cfg.quant.hindsight = v.as_bool().ok_or("`hindsight` must be bool")?;
            }
            set_num!(cfg.quant.hindsight_eta, t, &mut used, "hindsight_eta", as_float, f32);
            set_num!(cfg.quant.noise_reuse, t, &mut used, "noise_reuse", as_int, usize);
            check_unknown(t, &used, "quant")?;
        }

        if let Some(t) = doc.get("train") {
            let mut used = vec![];
            set_num!(cfg.train.steps, t, &mut used, "steps", as_int, usize);
            set_num!(cfg.train.batch, t, &mut used, "batch", as_int, usize);
            set_num!(cfg.train.lr, t, &mut used, "lr", as_float, f32);
            set_num!(cfg.train.momentum, t, &mut used, "momentum", as_float, f32);
            set_num!(cfg.train.weight_decay, t, &mut used, "weight_decay", as_float, f32);
            set_num!(cfg.train.lr_decay, t, &mut used, "lr_decay", as_float, f32);
            set_num!(cfg.train.eval_every, t, &mut used, "eval_every", as_int, usize);
            set_num!(cfg.train.eval_batches, t, &mut used, "eval_batches", as_int, usize);
            set_num!(cfg.train.seed, t, &mut used, "seed", as_int, u64);
            if let Some(v) = take(t, &mut used, "lr_milestones") {
                match v {
                    TomlValue::Array(items) if items.len() == 3 => {
                        for (i, it) in items.iter().enumerate() {
                            cfg.train.lr_milestones[i] =
                                it.as_float().ok_or("milestone must be number")? as f32;
                        }
                    }
                    _ => return Err("`lr_milestones` must be an array of 3 numbers".into()),
                }
            }
            check_unknown(t, &used, "train")?;
        }

        if let Some(t) = doc.get("fnt") {
            let mut used = vec![];
            set_num!(cfg.fnt.steps, t, &mut used, "steps", as_int, usize);
            set_num!(cfg.fnt.lr_base, t, &mut used, "lr_base", as_float, f32);
            check_unknown(t, &used, "fnt")?;
        }

        if let Some(t) = doc.get("profile") {
            // Delegated wholesale: StepProfile owns its schema (key
            // validation included), so serve job specs and run configs
            // cannot drift apart.
            cfg.profile = StepProfile::from_toml_section(t)?;
        }

        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.model.dim == 0 || self.model.depth == 0 {
            return Err("model dim/depth must be positive".into());
        }
        if self.quant.fwd_bits > 8 {
            return Err("fwd_bits must be <= 8".into());
        }
        if !(1..=6).contains(&self.quant.bwd_exp_bits) {
            return Err("bwd_exp_bits must be in 1..=6".into());
        }
        if self.quant.smp_samples == 0 || self.quant.noise_reuse == 0 {
            return Err("smp_samples and noise_reuse must be >= 1".into());
        }
        if self.train.steps == 0 || self.train.batch == 0 {
            return Err("train steps/batch must be positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn full_roundtrip_parse() {
        let cfg = RunConfig::from_toml(
            r#"
            name = "table1-luq"
            out_dir = "runs/table1"
            [model]
            kind = "transformer"
            dim = 256
            depth = 4
            heads = 8
            seq_len = 128
            vocab = 512
            [quant]
            fwd_bits = 4
            bwd = "luq"
            bwd_exp_bits = 3
            smp_samples = 2
            hindsight = true
            hindsight_eta = 0.1
            noise_reuse = 1
            [train]
            steps = 500
            batch = 16
            lr = 0.05
            lr_milestones = [0.3, 0.6, 0.9]
            [fnt]
            steps = 100
            lr_base = 0.001
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "table1-luq");
        assert_eq!(cfg.model.kind, ModelKind::Transformer);
        assert_eq!(cfg.model.dim, 256);
        assert_eq!(cfg.quant.bwd, BwdQuantScheme::Luq);
        assert_eq!(cfg.quant.smp_samples, 2);
        assert!(cfg.quant.hindsight);
        assert_eq!(cfg.train.steps, 500);
        assert_eq!(cfg.fnt.steps, 100);
    }

    #[test]
    fn profile_section_round_trips_through_run_config() {
        use crate::coordinator::layer_step::ForwardFormat;
        use crate::hw::qgemm::KernelPath;
        use crate::rng::NoiseEngine;

        let src = "[profile]\nformat = \"radix4_tpr\"\nbits = 3\nshards = 2\n\
                   kernel_path = \"portable\"\nnoise_engine = \"philox\"\n";
        let cfg = RunConfig::from_toml(src).unwrap();
        assert_eq!(cfg.profile.format(), ForwardFormat::Radix4Tpr);
        assert_eq!(cfg.profile.bits(), 3);
        assert_eq!(cfg.profile.shards().n_shards(), 2);
        assert_eq!(cfg.profile.kernel_path(), Some(KernelPath::Portable));
        assert_eq!(cfg.profile.noise_engine(), NoiseEngine::Philox);

        // parse → serialize → parse identity through RunConfig.
        let again = RunConfig::from_toml(&cfg.profile.to_toml()).unwrap();
        assert_eq!(again.profile, cfg.profile);

        // No [profile] section → paper defaults.
        assert_eq!(
            RunConfig::from_toml("name = \"x\"\n").unwrap().profile,
            StepProfile::paper_default()
        );
        // Bad profile values are loud.
        assert!(RunConfig::from_toml("[profile]\nbits = 9\n").is_err());
        assert!(RunConfig::from_toml("[profile]\nmystery = 1\n").is_err());
    }

    #[test]
    fn unknown_keys_rejected() {
        let err = RunConfig::from_toml("[model]\nwidht = 3").unwrap_err();
        assert!(err.contains("unknown key"), "{err}");
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(RunConfig::from_toml("[quant]\nbwd = \"nope\"").is_err());
        assert!(RunConfig::from_toml("[quant]\nbwd_exp_bits = 9").is_err());
        assert!(RunConfig::from_toml("[train]\nsteps = 0").is_err());
    }

    #[test]
    fn all_schemes_parse_their_names() {
        for s in [
            "fp32", "luq", "naive", "naive_sp", "naive_rdnp", "sp_rdnp", "ultralow", "int_sr",
            "int_rdn",
        ] {
            let parsed = BwdQuantScheme::parse(s).unwrap();
            assert_eq!(parsed.name(), s);
        }
    }
}
