//! Config substrate: a TOML-subset parser plus the typed run
//! configuration consumed by the coordinator and the `luq` CLI.
//!
//! Supported TOML subset (everything the run configs need): `[table]`
//! headers, `key = value` with strings, integers, floats, booleans, and
//! flat arrays; `#` comments. Unknown keys are rejected by the typed
//! layer so config typos fail loudly.

pub mod run;
pub mod toml;

pub use run::{
    BwdQuantScheme, FntConfig, ModelConfig, ModelKind, QuantConfig, RunConfig, TrainConfig,
};
pub use toml::{parse_toml, TomlValue};
