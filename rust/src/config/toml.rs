//! A small, strict TOML-subset parser (offline registry has no `toml`).

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `table name -> key -> value`; top-level keys live under table `""`.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse the TOML subset. Returns an error string with a line number on
/// malformed input.
pub fn parse_toml(src: &str) -> Result<TomlDoc, String> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut current = String::new();
    doc.entry(current.clone()).or_default();
    for (ln, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated table header", ln + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty table name", ln + 1));
            }
            current = name.to_string();
            doc.entry(current.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected `key = value`", ln + 1))?;
        let key = line[..eq].trim().to_string();
        if key.is_empty() {
            return Err(format!("line {}: empty key", ln + 1));
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {e}", ln + 1))?;
        let table = doc.entry(current.clone()).or_default();
        if table.insert(key.clone(), value).is_some() {
            return Err(format!("line {}: duplicate key `{key}`", ln + 1));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("unsupported: embedded quote".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items = split_top_level(inner)?;
        return Ok(TomlValue::Array(
            items
                .into_iter()
                .map(|i| parse_value(i.trim()))
                .collect::<Result<Vec<_>, _>>()?,
        ));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

fn split_top_level(s: &str) -> Result<Vec<&str>, String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => {
                depth = depth.checked_sub(1).ok_or("unbalanced brackets")?
            }
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = parse_toml(
            r#"
            # run config
            name = "demo"
            [model]
            kind = "transformer"  # decoder-only
            dim = 128
            dropout = 0.1
            tied = true
            dims = [64, 128, 256]
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"], TomlValue::Str("demo".into()));
        assert_eq!(doc["model"]["dim"], TomlValue::Int(128));
        assert_eq!(doc["model"]["dropout"], TomlValue::Float(0.1));
        assert_eq!(doc["model"]["tied"], TomlValue::Bool(true));
        assert_eq!(
            doc["model"]["dims"],
            TomlValue::Array(vec![
                TomlValue::Int(64),
                TomlValue::Int(128),
                TomlValue::Int(256)
            ])
        );
    }

    #[test]
    fn comments_respect_strings() {
        let doc = parse_toml(r##"path = "a#b" # real comment"##).unwrap();
        assert_eq!(doc[""]["path"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_toml("[unterminated").is_err());
        assert!(parse_toml("novalue =").is_err());
        assert!(parse_toml("= 3").is_err());
        assert!(parse_toml("x = @").is_err());
        assert!(parse_toml("x = 1\nx = 2").is_err());
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = parse_toml("a = -7\nb = 1e-3\nc = -2.5").unwrap();
        assert_eq!(doc[""]["a"], TomlValue::Int(-7));
        assert_eq!(doc[""]["b"], TomlValue::Float(1e-3));
        assert_eq!(doc[""]["c"], TomlValue::Float(-2.5));
    }

    #[test]
    fn as_float_promotes_ints() {
        assert_eq!(TomlValue::Int(3).as_float(), Some(3.0));
    }
}
