//! Offline shim for the `anyhow` crate, covering exactly the subset this
//! repository uses: [`Error`], [`Result`], the [`Context`] extension trait
//! on `Result` and `Option`, and the `anyhow!` / `bail!` macros.
//!
//! The container's crate registry is offline, so the real crates.io
//! `anyhow` cannot be fetched; this shim keeps the crate buildable with
//! identical call sites. Error values carry a message plus a context
//! chain, rendered innermost-last like real anyhow's `{:#}`/`Debug`.

use std::fmt;

/// A string-backed error with a chain of context frames.
pub struct Error {
    /// Context frames, outermost first; the root cause is last.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The outermost message (what `Display` prints).
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Real anyhow's `root_cause` analogue: the innermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, colon-separated, like real anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                writeln!(f, "    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: any std error converts into `Error` (this is what `?`
// uses). `Error` itself deliberately does NOT implement `std::error::Error`
// so this blanket impl cannot overlap `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as context frames.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Assert a condition, returning an [`Error`] on failure.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

// Sealed conversion powering `Context` for both std errors and `Error`
// itself — the same coherence trick real anyhow uses (valid because
// `Error` is a local type that does not implement `std::error::Error`).
mod private {
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }
    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }
}

/// Attach context to errors, as in real anyhow.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(ctx))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing file"));
    }

    #[test]
    fn context_wraps_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading artifact").unwrap_err();
        assert_eq!(e.to_string(), "loading artifact");
        assert_eq!(e.root_cause(), "missing file");
        assert!(format!("{e:#}").contains("loading artifact: missing file"));
    }

    #[test]
    fn with_context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("bad value {}", 3));
        let e = r.with_context(|| format!("step {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "step 7");
        assert_eq!(e.root_cause(), "bad value 3");

        let o: Option<u32> = None;
        assert_eq!(o.context("missing output").unwrap_err().to_string(), "missing output");
    }

    #[test]
    fn bail_and_ensure_return_early() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
        assert!(f(11).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn debug_renders_cause_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by") && d.contains("missing file"));
    }
}
