//! Offline stub of the `xla` (xla-rs) API surface the luq runtime uses.
//!
//! Two tiers:
//!
//! * **[`Literal`] is fully functional** — an in-memory shaped buffer with
//!   `vec1`/`reshape`/`array_shape`/`to_vec`/`decompose_tuple`, so host
//!   tensor round-trips (and their tests) work without any XLA install.
//! * **PJRT entry points are gated** — [`PjRtClient::cpu`] succeeds (the
//!   engine can be constructed and probed), but compiling or executing an
//!   HLO module returns [`Error::RuntimeUnavailable`]. On machines with
//!   the real PJRT plugin, point the `xla` dependency in `Cargo.toml` back
//!   at the real crate; no call sites change.

use std::fmt;
use std::path::Path;

/// Stub error type. Implements `std::error::Error` so call sites can wrap
/// it with `anyhow::Context` exactly like the real crate's error.
#[derive(Debug)]
pub enum Error {
    /// The operation needs the real XLA/PJRT runtime, which this offline
    /// stub does not provide.
    RuntimeUnavailable(&'static str),
    /// Literal-level usage error (shape/type mismatch).
    Literal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::RuntimeUnavailable(what) => write!(
                f,
                "XLA runtime unavailable in this offline build (needed for: {what}); \
                 link the real `xla` crate to enable PJRT execution"
            ),
            Error::Literal(msg) => write!(f, "literal error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the luq runtime exchanges with XLA.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    /// Present so downstream matches keep a reachable wildcard arm (the
    /// real crate has many more element types).
    Pred,
}

/// Sealed-ish conversion trait backing the generic `Literal` accessors.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn extract(data: &LiteralData) -> Option<Vec<Self>>;
    fn wrap(v: Vec<Self>) -> LiteralData;
}

#[derive(Clone, Debug)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn extract(data: &LiteralData) -> Option<Vec<f32>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
    fn wrap(v: Vec<f32>) -> LiteralData {
        LiteralData::F32(v)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn extract(data: &LiteralData) -> Option<Vec<i32>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
    fn wrap(v: Vec<i32>) -> LiteralData {
        LiteralData::I32(v)
    }
}

/// Row-major shape + element type of an array literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// An in-memory XLA literal: flat data + dims, or a tuple of literals.
#[derive(Clone, Debug)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Tuple literal (what a multi-output computation returns).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { data: LiteralData::Tuple(parts), dims: vec![] }
    }

    fn numel(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(_) => 0,
        }
    }

    /// Reshape to new dims (must preserve element count).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, LiteralData::Tuple(_)) {
            return Err(Error::Literal("cannot reshape a tuple literal".into()));
        }
        let want: i64 = dims.iter().product();
        if want as usize != self.numel() {
            return Err(Error::Literal(format!(
                "reshape {:?} -> {:?} changes element count",
                self.dims, dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
            LiteralData::Tuple(_) => {
                return Err(Error::Literal("tuple literal has no array shape".into()))
            }
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.data)
            .ok_or_else(|| Error::Literal(format!("literal is not {:?}", T::TY)))
    }

    /// Split a tuple literal into its parts.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.data, LiteralData::Tuple(vec![])) {
            LiteralData::Tuple(parts) => Ok(parts),
            other => {
                self.data = other;
                Err(Error::Literal("literal is not a tuple".into()))
            }
        }
    }
}

/// Parsed HLO module (stub: never constructible offline).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::RuntimeUnavailable("parsing HLO text"))
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer handle (stub: never materialized offline).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::RuntimeUnavailable("fetching device buffer"))
    }
}

/// Compiled executable handle (stub: never constructible offline).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::RuntimeUnavailable("executing a compiled module"))
    }
}

/// PJRT client. Construction succeeds so the coordinator can be built and
/// report a helpful error only when an artifact is actually compiled.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu-stub" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::RuntimeUnavailable("XLA compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn tuple_decompose() {
        let mut t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![1]);
    }

    #[test]
    fn pjrt_paths_are_gated_with_clear_error() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu-stub");
        let err = HloModuleProto::from_text_file("nope.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("XLA runtime unavailable"));
    }
}
